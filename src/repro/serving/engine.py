"""Serving engine: continuous batching over a fixed slot grid, with the
FMMU page manager owning logical->physical KV translation.

Prefill writes each request's KV into pool blocks named by the FMMU
block table; decode steps run the whole slot batch through
Model.decode_step against the **device-resident incremental block
table** (a member of the FMMU state pytree, kept coherent by the same
fused call that commits each map write — see DESIGN.md). The decode
hot loop performs zero full-map retranslations and at most one fused
map call per step: page growth for all slots crossing a page boundary
is batched into ONE allocation + ONE ``_xlate``, and paused/invalid
slot masking happens inside the decode jit (no host table roundtrip;
the only per-step host sync is the next-token transfer). Pool
exhaustion preempts the longest victim sequence to the host tier
(swap_out, CondUpdate-guarded) — the serving analogue of the paper's
GC path.

K-step fused decode macro-steps (DESIGN.md "Macro-step decode")
---------------------------------------------------------------
With ``macro_k >= 2`` the steady-state inner loop leaves the host
entirely: ONE donated jit runs a ``lax.scan`` of K decode steps —
attention + greedy sampling + page-boundary detection + device-side
block allocation (the ServingMapState free stack) + fused map commit
per step — and the host performs exactly one dispatch and one
device->host sync (tokens + allocation log) per K tokens. The host
pool stays authoritative at macro-step boundaries only: admission,
swap, preemption and the reconciliation of allocator deltas
(``KVPageManager.reconcile_macro``) happen between scans, and the
engine falls back to the single-step path only when the decoding
lanes' worst-case growth cannot be made to fit the device pool even
by swapping (proactive check; the in-graph ``oob`` flag is the
reactive backstop) — e.g. with no host tier configured. Slots
that finish mid-scan (EOS / max_new budget) are retired *inside* the
scan with single-step pause semantics — masked to the scratch block,
context frozen, no further growth — and freed by the host at the
boundary, so a K-step scan is bit-identical to K single steps.

Non-blocking host-tier swap pipeline (DESIGN.md, ISSUE 4)
---------------------------------------------------------
The paper's FMMU services outstanding requests while a map-cache miss
is handled; the serving analogue is a slot whose KV pages live in the
host tier. With ``nonblocking_swap`` (the default) such slots no
longer drop the engine out of the fused macro path: they are
**swap-pending lanes** — masked inside the scan from the
``ServingMapState.swap_pending`` residency lane exactly like paused
slots — while every other slot keeps decoding. A boundary scheduler
(``_swap_schedule``) plans tier moves between macro-steps: it swaps
out victims until the residents' worst-case K-step growth fits the
free pool, swaps waiting slots back in FIFO, and rotates by aging
(``swap_patience``) so sustained 2x oversubscription runs steady-state
with ZERO single-step fallbacks (counter-enforced). Swap data
movement itself is one donated jitted gather/scatter per swap with the
CondUpdate map commits riding the single-probe fused translate
(``KVPageManager.swap_out/swap_in``, ``check=False``: the host never
blocks on a swap). ``nonblocking_swap=False`` restores the PR-3
fall-back-on-pressure behavior (the serve_bench baseline).

Channel-sharded map (DESIGN.md "Channel-sharded map pipeline", ISSUE 5)
-----------------------------------------------------------------------
``ServeEngine(channels=N)`` shards the FMMU map state across N
channels by the static hash ``dlpn mod N`` (KVPageManager above). The
macro path then PRE-COMMITS each scan's worst-case growth at the
boundary — one channel-aware pool allocation in the scan's own
step-major pop order plus ONE fused sharded map dispatch — and runs a
pure-decode K-step scan (``_macro_sharded_fn``) against the table
materialized from the channel shards once per dispatch. Eligibility
and the swap scheduler's reserve arithmetic compare need against free
blocks PER CHANNEL (a dry channel is real pressure even while others
hold blocks). ``channels=1`` (default) is the unsharded path above,
bit-identical.

Continuous-batching admission rides the same boundaries: ``_admit``
spends at most ``admit_tokens`` prompt tokens per scheduling round;
a longer prompt is chunk-prefilled — its first chunk goes through the
prefill kernel and the remainder streams through the decode scans as
**forced lanes** (the scan consumes the known prompt token instead of
the sampled one and the boundary prediction is discarded), so
admission never stalls the decode batch.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import journal as jl
from repro.core.counters import COUNTERS
from repro.core.faults import FaultPlane, SwapFault
from repro.core.fmmu import batch as fb
from repro.core.fmmu.types import NIL
from repro.models import transformer
from repro.models.common import Runtime
from repro.models.model import Model, _src_len
from repro.paging.kv_manager import KVPageManager
from repro.paging.pool import OutOfBlocks
from repro.serving.config import (DurabilityConfig, FaultPolicy,
                                  GCConfig, ServeConfig)

# Host-cost counters (the XLATE_CALLS pattern): one MACRO_DISPATCHES
# bump per macro-step jit call, one HOST_SYNCS bump per blocking
# device->host readback. tests/test_serving.py asserts steady-state
# macro decode costs exactly one of each per K steps. The names alias
# registry cells (core/counters.py): same list objects, also visible
# to COUNTERS.snapshot()/delta().
MACRO_DISPATCHES = COUNTERS.cell("engine.macro_dispatches")
HOST_SYNCS = COUNTERS.cell("engine.host_syncs")


@dataclasses.dataclass
class Request:
    rid: int
    tokens: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    src_emb: Optional[jnp.ndarray] = None
    prefix_emb: Optional[jnp.ndarray] = None
    # chunked admission: prompt tokens not yet fed to the model — they
    # stream through the decode path as forced lanes (predictions over
    # this range are discarded; the true next token is known)
    pending_prompt: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, model: Model, params, *,
                 config: Optional[ServeConfig] = None,
                 fault_plane: Optional[FaultPlane] = None,
                 **legacy):
        # typed-config constructor (ISSUE 9 API redesign): the primary
        # form is ServeEngine(model, params, config=ServeConfig(...));
        # the historical flat keyword set still works through ONE
        # deprecation shim and builds the identical config value
        # (bit-equivalence unit-tested in tests/test_gc.py). The fault
        # PLANE stays a runtime argument on both forms — it is a
        # stateful schedule, not configuration.
        if config is not None and legacy:
            raise TypeError(
                "pass config=ServeConfig(...) OR legacy keyword "
                f"arguments, not both (got {sorted(legacy)})")
        if config is None:
            warnings.warn(
                "keyword-style ServeEngine construction is deprecated; "
                "pass config=ServeConfig(...)",
                DeprecationWarning, stacklevel=2)
            config = ServeConfig.from_legacy(**legacy)
        self.config = config
        n_slots = config.n_slots
        max_ctx = config.max_ctx
        n_device_blocks = config.n_device_blocks
        n_host_blocks = config.n_host_blocks
        eos_id = config.eos_id
        macro_k = config.macro_k
        nonblocking_swap = config.nonblocking_swap
        admit_tokens = config.admit_tokens
        swap_patience = config.swap_patience
        channels = config.channels
        use_mesh = config.use_mesh
        max_swap_retries = config.faults.max_swap_retries
        swap_backoff_cap = config.faults.swap_backoff_cap
        watchdog_rounds = config.faults.watchdog_rounds
        journal_path = config.durability.journal_path
        snapshot_every = config.durability.snapshot_every
        self.m = model
        self.cfg = model.cfg
        self.rt = model.rt
        self.params = params
        self.n_slots = n_slots
        self.page = self.rt.page_size
        self.max_pages = -(-max_ctx // self.page)
        n_dev = n_device_blocks or (n_slots * self.max_pages)
        # ISSUE-5: channels > 1 shards the FMMU map state (CMT, backing,
        # incremental table, free-list allocator, swap lanes) across an
        # N-channel mesh by the static hash owner(dlpn) = dlpn mod N;
        # the decode scans consume the table materialized from the
        # shards at macro-step boundaries. channels=1 (default) is the
        # unsharded pre-ISSUE-5 path, bit-identical.
        self.channels = int(channels)
        # the engine pins the portable vmap lowering for its map manager
        # even when >= C devices are visible (use_mesh=None): the model
        # jits carry single-device sharding constraints, and feeding
        # them mesh-committed tables/caches trips jax's incompatible-
        # device check. Model-and-map co-residency on one mesh is the
        # ROADMAP "real multi-host channel mesh" item; the shard_map
        # lowering itself is pinned bit-identical to vmap at the map
        # level (tests/test_sharded_map.py), so nothing is lost in
        # results. An explicit use_mesh=True is forwarded for setups
        # whose model is already mesh-sharded.
        # the GC plane (ISSUE 9 tentpole): config.gc arms the map's
        # live lane (per-block live-page counts maintained INSIDE the
        # fused translate commits) and the boundary victim walk below.
        # gc=None keeps live=None — an absent pytree leaf, so every
        # traced graph is bit-identical to the pre-GC engine
        # (jaxpr-identity asserted in tests/test_gc.py).
        self.gc = config.gc
        # the prefix-sharing plane (ISSUE 10 tentpole): config.prefix
        # arms the map's refcnt lane (per-block mapping counts, the
        # live lane's twin) plus the radix admission path and the COW
        # frontier scan below. prefix=None keeps refcnt=None — an
        # absent pytree leaf, so every traced graph is bit-identical
        # to the pre-sharing engine (tests/test_prefix.py).
        self.prefix = config.prefix
        self.kvm = KVPageManager(n_slots, self.max_pages, n_dev,
                                 n_host_blocks, channels=self.channels,
                                 use_mesh=bool(use_mesh),
                                 faults=fault_plane,
                                 track_live=self.gc is not None,
                                 track_refs=self.prefix is not None)
        if self.prefix is not None:
            self.kvm.prefix_max_nodes = self.prefix.max_nodes
        # sharing only applies to pure paged-attention state: a mamba
        # layer's recurrent state is per-slot and position-dependent,
        # so a skipped prefill cannot be reconstructed from shared KV
        # pages (requests with prefix/src embeddings are gated per
        # request in _share_ok for the same reason)
        self._share_model_ok = not any(
            self.cfg.layer_kind(j) == "mamba"
            for j in range(self.cfg.period))
        src_len = _src_len(self.cfg, max_ctx)
        # +1 scratch block: unmapped table entries (inactive slots) write
        # their garbage KV there instead of corrupting block 0
        self.scratch_block = n_dev + n_host_blocks
        self.caches = transformer.init_decode_caches(
            self.cfg, self.rt, n_slots, self.max_pages,
            n_dev + n_host_blocks + 1, self.rt.compute_dtype,
            src_len=src_len)
        # int32 end-to-end: the decode jit consumes these every step and
        # an int64 numpy array would pay a device-side convert per call
        self.ctx_lens = np.zeros(n_slots, np.int32)
        self.src_cap = src_len
        self.src_lens = np.zeros(n_slots, np.int32)
        self.active: Dict[int, Request] = {}
        self.eos_id = eos_id
        self.queue: Deque[Request] = deque()
        self._rid = 0
        # caches (arg 2) are DONATED: the KV pool is updated in place
        # instead of functionally copied every step. Callers always
        # rebind self.caches from the return (same contract as the
        # donated FMMU state pytree). The live-page bucket (arg 7) is
        # STATIC: the block table is sliced to the smallest power-of-2
        # page count covering every mapped page before attention runs,
        # so decode work scales with actual context, not max_ctx; each
        # bucket traces once (<= log2(max_pages) compilations per run).
        self._decode = jax.jit(self._decode_fn, donate_argnums=(2,),
                               static_argnums=(7,))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(2,))
        # K-step fused macro-steps: state pytree (arg 1) and caches
        # (arg 2) both DONATED — the whole inner loop mutates in place.
        # Two static specializations (cached separately, never
        # re-traced): `simple` drops the retirement machinery for the
        # common steady state where no slot can finish mid-scan
        # (eos_id < 0 and every budget >= K); `full` keeps EOS/budget
        # retirement with pause semantics.
        self.macro_k = int(macro_k)
        self._macro = self._macro_simple = None
        self._macro_sh = self._macro_sh_simple = None
        if self.macro_k >= 2:
            if self.channels == 1:
                self._macro = jax.jit(self._macro_fn,
                                      donate_argnums=(1, 2),
                                      static_argnums=(10,))
                self._macro_simple = jax.jit(
                    functools.partial(self._macro_fn, simple=True),
                    donate_argnums=(1, 2), static_argnums=(10,))
            else:
                # channel-sharded scans: growth is pre-committed at the
                # boundary, so the scan takes no map state — only the
                # caches donate and the sharded table materializes once
                # inside the jit (static arg 9 = live-page bucket)
                self._macro_sh = jax.jit(self._macro_sharded_fn,
                                         donate_argnums=(1,),
                                         static_argnums=(9,))
                self._macro_sh_simple = jax.jit(
                    functools.partial(self._macro_sharded_fn,
                                      simple=True),
                    donate_argnums=(1,), static_argnums=(9,))
        self._macro_on = self.macro_k >= 2
        self.min_page_bucket = 4
        # non-blocking swap pipeline + continuous-batching admission
        # (module docstring): swap-pending slots are masked scan lanes,
        # the boundary scheduler rotates residency by aging, and
        # admission spends at most admit_tokens prompt tokens per round
        # (None = admit whole prompts, the pre-ISSUE-4 behavior)
        self.nonblocking_swap = bool(nonblocking_swap)
        if admit_tokens is not None and admit_tokens <= 0:
            raise ValueError(
                f"admit_tokens={admit_tokens}: a non-positive budget "
                "would never admit anything (pass None for unlimited)")
        self.admit_tokens = admit_tokens
        self.swap_patience = int(swap_patience)
        self._boundary = 0
        self._pending_since: Dict[int, int] = {}
        self._resident_since: Dict[int, int] = {}
        # fault plane + recovery machinery (ISSUE 6, core/faults.py):
        # swap failures retry with capped exponential backoff and a
        # per-slot counter — a persistent failer is QUARANTINED (pages
        # freed, request requeued at the admission front, reservation
        # released the same boundary); a macro-boundary watchdog
        # force-quarantines any lane with no token progress for
        # watchdog_rounds boundaries (None: 8*patience with a plane,
        # off without — a healthy engine cannot strand a lane)
        self.faults = fault_plane
        self.max_swap_retries = int(max_swap_retries)
        self.swap_backoff_cap = int(swap_backoff_cap)
        if watchdog_rounds is None:
            watchdog_rounds = (8 * max(1, self.swap_patience)
                               if fault_plane is not None else 0)
        self.watchdog_rounds = int(watchdog_rounds)
        self._swap_fails: Dict[int, int] = {}     # slot -> consecutive
        self._retry_at: Dict[int, int] = {}       # slot -> boundary gate
        self._progress: Dict[int, tuple] = {}     # slot -> (out, pend, bd)
        self.metrics = {"prefills": 0, "prefill_tokens": 0,
                        "decode_steps": 0, "preemptions": 0,
                        "generated": 0, "macro_steps": 0,
                        "macro_fallbacks": 0, "swaps_out": 0,
                        "swaps_in": 0, "chunked_prefills": 0,
                        "swap_faults": 0, "quarantines": 0,
                        "watchdog_quarantines": 0, "requeues": 0,
                        "recoveries": 0, "gc_walks": 0, "gc_moves": 0,
                        "gc_victims": 0, "shared_admits": 0,
                        "shared_pages": 0, "cow_moves": 0}
        # crash-consistency journal (ISSUE 7, core/journal.py): when
        # attached, every host commit point appends a sequence-numbered
        # record and every `snapshot_every`-th macro boundary writes a
        # full atomic state snapshot. Detached (default) the engine is
        # byte-for-byte the PR-6 engine — the hooks are `is not None`
        # guards on host code, so the traced graphs cannot differ
        # (jaxpr-identity asserted in tests/test_journal.py).
        self.journal: Optional["jl.Journal"] = None
        self.snapshot_every = int(snapshot_every)
        self._finished: Dict[int, List[int]] = {}
        self._ever_admitted: set = set()
        self._lane_base = 0
        self.last_recovery: Optional[dict] = None
        if journal_path:
            self.attach_journal(journal_path)

    # ------------------------------------------------------------- API
    def submit(self, tokens: List[int], max_new: int = 16, *,
               src_emb=None, prefix_emb=None) -> int:
        rid = self._rid
        self._rid += 1
        self.queue.append(Request(rid, list(tokens), max_new,
                                  src_emb=src_emb, prefix_emb=prefix_emb))
        if self.journal is not None:
            assert src_emb is None and prefix_emb is None, \
                "journaled serving persists token prompts only"
            self.journal.append(jl.SUBMIT,
                                {"rid": rid,
                                 "tokens": [int(t) for t in tokens],
                                 "max_new": int(max_new), "lanes": 0})
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        done: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            if not self.step(done):
                break
        return done

    def reset(self, fault_plane: Optional[FaultPlane] = None):
        """Fresh serving state on the SAME compiled jits: the decode /
        prefill / macro / swap closures are bound methods whose traces
        are per-instance, so a new ServeEngine recompiles everything —
        this instead reinitializes map, pool, caches and bookkeeping
        (optionally installing a new fault plane) and keeps every
        compiled function. The chaos harness (tests/chaos/) replays
        hundreds of fault schedules per engine through this."""
        self.kvm.reset(faults=fault_plane)
        self.faults = fault_plane
        self.caches = transformer.init_decode_caches(
            self.cfg, self.rt, self.n_slots, self.max_pages,
            self.scratch_block + 1, self.rt.compute_dtype,
            src_len=self.src_cap)
        self.ctx_lens[:] = 0
        self.src_lens[:] = 0
        self.active = {}
        self.queue = deque()
        self._rid = 0
        self._boundary = 0
        self._pending_since = {}
        self._resident_since = {}
        self._swap_fails = {}
        self._retry_at = {}
        self._progress = {}
        if self.journal is not None:
            self.journal.close()
        self.journal = None        # kvm.reset detached its hook already
        self._finished = {}
        self._ever_admitted = set()
        for k in self.metrics:
            self.metrics[k] = 0

    # -------------------------------------- crash consistency (ISSUE 7)
    def attach_journal(self, path: str,
                       snapshot_every: Optional[int] = None,
                       resume: bool = False) -> "jl.Journal":
        """Arm crash-consistent journaling at `path`: every host commit
        point appends a record, every snapshot_every-th boundary writes
        an atomic snapshot, and the fault plane's crash axis (if any)
        is consumed per append. Writes the base snapshot immediately —
        recovery always has a floor to replay from."""
        if snapshot_every is not None:
            self.snapshot_every = int(snapshot_every)
        self.journal = jl.Journal(path, faults=self.faults,
                                  resume=resume)
        self.kvm.journal = self.journal
        # lane-integrity baseline: device commit lanes vs journaled
        # lanes advance in lockstep from here (journal_lane_check)
        self._lane_base = self._device_lanes()
        self.journal.lanes_base = self.journal.commit_lanes
        self._write_snapshot()
        return self.journal

    def _journal_finish(self, r: Request):
        """FINISH precedes the slot's FREE in the journal: a crash
        between the two leaves an orphan mapping that replay's cleanup
        pass re-frees (the request is durably done either way)."""
        if self.journal is None:
            return
        out = [int(t) for t in r.out[:r.max_new]]
        self._finished[r.rid] = out
        self.journal.append(jl.FINISH,
                            {"rid": r.rid, "out": out, "lanes": 0})

    def _device_lanes(self) -> int:
        """Total committed map-write lanes on device (the ISSUE-7
        commit_seq lane, summed over channel shards). A readback —
        diagnostics and tests only, never the hot path."""
        return int(np.asarray(jax.device_get(
            fb.commit_seq_vec(self.kvm.state))).sum())

    def journal_lane_check(self) -> bool:
        """Integrity cross-check at a quiesced boundary: the device's
        commit_seq lane and the journal's cumulative record lanes must
        have advanced identically since attach. (Between a macro scan
        and its reconcile record the two legitimately diverge — call
        this after ``step`` returns, not mid-dispatch.)"""
        if self.journal is None:
            return True
        return (self._device_lanes() - self._lane_base
                == self.journal.commit_lanes - self.journal.lanes_base)

    def _write_snapshot(self) -> str:
        """One atomic full-state snapshot: the manager's host truth
        (page lists + pool allocator incl. free-list order) plus the
        engine's request/admission state. Host bookkeeping only — no
        device arrays, no KV data (volatile by design: in-flight
        requests restart via the quarantine discipline)."""
        st = self.kvm.snapshot_state()
        st["queue"] = [r.rid for r in self.queue]
        st["ever_admitted"] = sorted(self._ever_admitted)
        st["active"] = [[r.rid, r.slot] for r in self.active.values()]
        st["done"] = {int(r): o for r, o in self._finished.items()}
        st["submits"] = {
            r.rid: [[int(t) for t in r.tokens], int(r.max_new)]
            for r in list(self.queue) + list(self.active.values())}
        st["rid"] = self._rid
        st["boundary"] = self._boundary
        return self.journal.snapshot(st)

    def recover(self, path: str,
                fault_plane: Optional[FaultPlane] = None,
                snapshot_every: Optional[int] = None
                ) -> Dict[int, List[int]]:
        """Sudden-power-off recovery: rebuild this engine from the
        journal directory at `path` (latest snapshot + record replay +
        OOB reverse-map scan for a torn tail — core/journal.py), then
        restart every in-flight request with the quarantine discipline
        — pages freed, output reset, requeued at its admission
        position — and re-arm the journal with a fresh base snapshot.

        Requeue ordering (satellite 2): the recovered admission deque
        is [crash-time front-requeued quarantined requests] +
        [in-flight requests, admission order] + [never-admitted
        arrivals, FIFO]. Quarantined requests were deliberately pushed
        AHEAD of the admission point before the crash, so recovery
        must not reorder them behind the recovered in-flight ones; the
        crash-time queue can only be (requeued..., pristine...) —
        appendleft builds the front, append the back — so the split
        point is the first never-admitted rid.

        Returns the durably finished outputs (rid -> tokens); resumed
        decode is bit-identical to an uncrashed run (greedy
        determinism). ``last_recovery`` carries MTTR inputs: replayed
        record count, torn/oob_scan flags, and wall recovery time."""
        t0 = time.perf_counter()
        rec = jl.replay(path)
        n_recov = self.metrics.get("recoveries", 0)
        self.reset(fault_plane)
        self.kvm.restore_mapping(rec)
        # in-flight restart (KV was volatile): free surviving pages —
        # journal detached, so these frees are folded into the fresh
        # base snapshot rather than logged — and rebuild Requests
        requeued: List[Request] = []
        for rid, slot in rec.active.items():
            if slot in self.kvm.seq_pages:
                self.kvm.free_seq(slot)
            toks, mx = rec.submits[rid]
            requeued.append(Request(rid, list(toks), int(mx)))
        qreqs = []
        for rid in rec.queue:
            toks, mx = rec.submits[rid]
            qreqs.append(Request(rid, list(toks), int(mx)))
        k = 0
        while k < len(qreqs) and qreqs[k].rid in rec.ever_admitted:
            k += 1
        self.queue = deque(qreqs[:k] + requeued + qreqs[k:])
        self._rid = int(rec.rid)
        self._boundary = int(rec.boundary)
        self._finished = {int(r): list(o) for r, o in rec.done.items()}
        self._ever_admitted = (set(rec.ever_admitted)
                               | set(rec.active.keys()))
        self.metrics["requeues"] += len(requeued)
        self.metrics["recoveries"] = n_recov + 1
        # re-arm: truncate the torn tail, continue the sequence, seal
        # with a fresh snapshot — a second crash replays from here
        self.attach_journal(path, snapshot_every=snapshot_every,
                            resume=True)
        self.last_recovery = {
            "snap_seq": int(rec.snap_seq),
            "last_seq": int(rec.last_seq),
            "replayed": int(rec.replayed),
            "torn": bool(rec.torn), "oob_scan": bool(rec.oob_scan),
            "requeued": len(requeued),
            "recover_s": time.perf_counter() - t0}
        return {int(r): list(o) for r, o in rec.done.items()}

    # ------------------------------------------------------------- steps
    def step(self, done: Dict[int, List[int]]) -> bool:
        """One scheduling round: admissions (budgeted), boundary swap
        planning, then either ONE fused K-step macro-step (swap-pending
        slots masked as paused lanes) or one single decode step."""
        self._admit()
        if not self.active:
            return bool(self.queue)
        # one scheduling round = one boundary (the aging/backoff/
        # watchdog clock); counted here so fallback rounds age too
        self._boundary += 1
        if self.watchdog_rounds:
            self._watchdog()
            if not self.active:
                return bool(self.queue)
        if self._macro_on and self.nonblocking_swap:
            self._swap_schedule()
        # COW frontier (ISSUE 10): shared pages the coming writes
        # would touch go private here, before any decode dispatch
        # (and before the macro paths' allocator sync — the copies'
        # destination pops must reach the device mirror)
        if self.prefix is not None:
            self._cow_boundary()
        if self._macro_eligible():
            self._macro_decode_step(done)
        else:
            if self._macro_on:
                self.metrics["macro_fallbacks"] += 1
            self._decode_step(done)
        # GC watermark policy (ISSUE 9 tentpole): when any channel's
        # free device blocks fall below the watermark, run ONE budgeted
        # victim walk at this boundary — never inside the decode path
        if self.gc is not None:
            self._gc_boundary()
        # macro-boundary snapshot cadence (ISSUE 7): every
        # snapshot_every-th scheduling round seals the journal with a
        # full atomic state snapshot, bounding replay length (MTTR)
        if self.journal is not None and self.snapshot_every \
                and self._boundary % self.snapshot_every == 0:
            self._write_snapshot()
        return bool(self.active or self.queue)

    def _free_slots(self) -> List[int]:
        used = {r.slot for r in self.active.values()}
        return [s for s in range(self.n_slots) if s not in used]

    def _admit(self):
        """Continuous-batching admission: admit + prefill queued
        requests under a per-round token budget (``admit_tokens``). A
        prompt longer than the remaining budget is CHUNK-prefilled:
        its first chunk goes through the prefill kernel now and the
        remainder streams through the decode scans as forced lanes, so
        one long prompt cannot stall the decode batch for a round."""
        if not self.queue:
            return
        budget = self.admit_tokens
        free = self._free_slots()
        while self.queue and free:
            req = self.queue[0]
            slot = free[0]
            chunk = len(req.tokens)
            if budget is not None:
                if budget <= 0:
                    return                  # token budget spent this round
                chunk = min(chunk, budget)
            # prefix sharing (ISSUE 10): walk the radix tree over the
            # prompt's page groups; any cached prefix maps this slot's
            # leading dlpns at the SHARED blocks and skips their
            # prefill entirely (zero FLOPs, zero programs, zero budget)
            groups = shared_blocks = None
            if self._share_ok(req):
                groups = self.kvm.page_groups(req.tokens, self.page)
                shared_blocks = self.kvm.match_prefix(groups)
            # on-demand allocation: admission reserves only the chunk
            # (+prefix) pages that prefill actually writes; decode grows
            # the mapping page-by-page (batched, one fused map call per
            # step) instead of parking max_new worth of blocks up front
            n_prefix = (req.prefix_emb.shape[0]
                        if req.prefix_emb is not None else 0)
            if shared_blocks:
                n_pages = len(shared_blocks)
            else:
                n_pages = -(-(chunk + n_prefix) // self.page)
                n_pages = max(1, min(n_pages, self.max_pages))
            try:
                self.kvm.new_seq(slot, n_pages, shared=shared_blocks)
            except OutOfBlocks:
                if not self._preempt(exclude=slot):
                    return
                continue
            self.queue.popleft()
            free.pop(0)
            req.slot = slot
            self.active[req.rid] = req
            self._ever_admitted.add(req.rid)
            self._resident_since[slot] = self._boundary
            if self.journal is not None:
                self.journal.append(
                    jl.ADMIT, {"rid": req.rid, "slot": int(slot),
                               "lanes": 0})
            if shared_blocks:
                # the cached prefix IS the context: start the slot at
                # n_skip and stream the (always >= 1) remaining prompt
                # tokens through the decode scans as forced lanes —
                # the chunked-prefill machinery, so outputs stay
                # bit-identical to an unshared admission. Keeping the
                # final token out of the skip even when the whole
                # prompt is cached makes the last forced step produce
                # the first output logits; its page is relocated
                # copy-on-write before the write lands (_cow_boundary).
                n_skip = min(sum(len(g) for g in
                                 groups[:len(shared_blocks)]),
                             len(req.tokens) - 1)
                self.ctx_lens[slot] = n_skip
                req.pending_prompt = list(req.tokens[n_skip:])
                self.metrics["shared_admits"] += 1
                self.metrics["shared_pages"] += len(shared_blocks)
            else:
                self._do_prefill(req, chunk)
                if budget is not None:
                    budget -= chunk

    # ------------------------------------- prefix sharing (ISSUE 10)
    def _share_ok(self, req: Request) -> bool:
        """Prefix sharing applies to plain token prompts on attention
        -only state long enough to be worth the tree walk; prefix/src
        embeddings carry per-slot state the shared pages don't hold."""
        return (self.prefix is not None and self._share_model_ok
                and req.prefix_emb is None and req.src_emb is None
                and len(req.tokens) >= self.prefix.min_tokens)

    def _register_prompt(self, req: Request):
        """Pin a fully-prefilled prompt's pages into the radix tree
        (idempotent — register_prefix skips cached keys) so later
        admissions can map them. Called at every prompt-completion
        site: full prefill, single-step drain, macro-scan drain."""
        if self._share_ok(req):
            self.kvm.register_prefix(
                req.slot, self.kvm.page_groups(req.tokens, self.page))

    def _cow_boundary(self):
        """Relocate diverging shared pages BEFORE this round's decode
        writes land (ISSUE 10): every resident lane's write-frontier
        page and beyond must be private by the time the scan commits
        KV there. One batched CondUpdate + fused KV row copy — the GC
        walk's machinery and stale-lane discipline. On exhaustion,
        preempt one victim to the host tier and retry once (the copy
        itself cannot be deferred: the write is about to commit)."""
        kvm = self.kvm
        if not kvm.has_shared():
            return
        fronts = {r.slot: int(self.ctx_lens[r.slot]) // self.page
                  for r in self.active.values()
                  if kvm.is_resident(r.slot) and kvm.has_shared(r.slot)}
        if not fronts:
            return
        pools = [self.caches["pool_k"], self.caches["pool_v"]]
        try:
            pools, n = kvm.cow_writes(fronts, pools, block_axis=2)
        except OutOfBlocks:
            if not self._preempt(exclude=-1):
                raise
            pools, n = kvm.cow_writes(fronts, pools, block_axis=2)
        self.caches["pool_k"], self.caches["pool_v"] = pools
        self.metrics["cow_moves"] += n

    def _preempt(self, exclude: int) -> bool:
        """Swap the longest active sequence that still holds device
        pages out to the host tier (an already-swapped victim would
        move nothing). False when no such victim exists or the host
        tier itself cannot take the blocks."""
        if self.kvm.pool.n_host == 0:
            return False
        victims = [r for r in self.active.values() if r.slot != exclude]
        for victim in sorted(victims, key=lambda r: self.ctx_lens[r.slot],
                             reverse=True):
            if self._swap_out_slot(victim.slot, check=True):
                self.metrics["preemptions"] += 1
                return True
            if victim.rid not in self.active:
                # the failed swap quarantined the victim (retries
                # exhausted): its pages are free right now, which is
                # all the caller needed (satellite-6 same-boundary
                # release)
                return True
        return False

    def _ensure_resident(self):
        """Swap in any host-tier pages of active sequences (before decode).
        Sequences that cannot come back yet PAUSE (they are excluded from
        the decode batch) until device blocks free up. Tier predicate:
        KVPageManager.is_resident (BlockPool.is_host underneath)."""
        if self.kvm.pool.n_host == 0:
            return    # no host tier: nothing can ever be swapped out
        for r in sorted(self.active.values(),
                        key=lambda r: len(self.kvm.seq_pages.get(r.slot, []))):
            if not self.kvm.is_resident(r.slot) \
                    and not self._backed_off(r.slot):
                # a False return = stays swapped & paused; retried next
                # round (same OutOfBlocks semantics as before the dedup)
                self._swap_in_slot(r.slot, check=True)

    # --------------------------------------------- boundary swap planner
    def _growth_need(self, slot: int) -> int:
        """Total worst-case device blocks `slot` can pop during one
        K-step scan (sum of ``_growth_need_ch`` — the one home of the
        growth arithmetic the scan body and the reconcile replay
        mirror)."""
        return int(self._growth_need_ch(slot).sum())

    def _growth_need_ch(self, slot: int) -> np.ndarray:
        """Worst-case K-step growth of `slot` per owner channel
        ([total] at channels=1): page p pops from channel
        (slot * max_pages + p) mod C, so the reserve checks must fit
        per channel, not in aggregate. Same page-boundary arithmetic
        as the scan body and the reconcile replay (mirror
        protocol)."""
        C = self.channels
        have = len(self.kvm.seq_pages[slot])
        target = min(self.max_pages,
                     -(-(int(self.ctx_lens[slot]) + self.macro_k)
                       // self.page))
        out = np.zeros(C, np.int64)
        base = slot * self.max_pages
        for p in range(have, target):
            out[(base + p) % C] += 1
        return out

    def _swap_out_slot(self, slot: int, check: bool = False) -> bool:
        """Move one slot's device pages to the host tier through the
        fused swap jit; the ONE home for the engine's swap-out protocol
        (pool pack + caches rebind + counters + residency stamps),
        shared by the boundary scheduler (check=False: no readback,
        the non-blocking mode) and the single-step preempt path
        (check=True, the PR-3-faithful blocking guard). The slot
        becomes a swap-pending lane — masked in the next scans — until
        it is swapped back in."""
        kvm = self.kvm
        if kvm.n_device_pages(slot) == 0:
            return False
        pools = [self.caches["pool_k"], self.caches["pool_v"]]
        try:
            pools, moved = kvm.swap_out(slot, pools, block_axis=2,
                                        check=check)
        except SwapFault:
            self._note_swap_fault(slot)   # backoff, maybe quarantine
            return False
        except OutOfBlocks:
            return False               # host tier full: nothing moved
        self.caches["pool_k"], self.caches["pool_v"] = pools
        if not moved:
            return False
        self._swap_fails.pop(slot, None)
        self._retry_at.pop(slot, None)
        self._progress.pop(slot, None)
        self.metrics["swaps_out"] += 1
        self._pending_since[slot] = self._boundary
        return True

    def _swap_in_slot(self, slot: int, check: bool = False) -> bool:
        """Swap-out's dual: same single home, same check semantics."""
        kvm = self.kvm
        pools = [self.caches["pool_k"], self.caches["pool_v"]]
        try:
            pools, moved = kvm.swap_in(slot, pools, block_axis=2,
                                       check=check)
        except SwapFault:
            self._note_swap_fault(slot)
            return False
        except OutOfBlocks:
            return False
        self.caches["pool_k"], self.caches["pool_v"] = pools
        if not moved:
            return False
        self._swap_fails.pop(slot, None)
        self._retry_at.pop(slot, None)
        self._progress.pop(slot, None)
        self.metrics["swaps_in"] += 1
        self._resident_since[slot] = self._boundary
        self._pending_since.pop(slot, None)
        return True

    # --------------------------------------- fault recovery (ISSUE 6)
    def _note_swap_fault(self, slot: int):
        """An injected swap failure left state untouched (SwapFault
        raises pre-mutation): back the slot off for min(2^fails,
        swap_backoff_cap) boundaries — capped exponential — and
        QUARANTINE it once max_swap_retries consecutive attempts have
        failed (a wedged slot must not pin its reservation forever)."""
        self.metrics["swap_faults"] += 1
        n = self._swap_fails.get(slot, 0) + 1
        self._swap_fails[slot] = n
        if n >= self.max_swap_retries:
            self._quarantine(slot, "swap retries exhausted")
        else:
            self._retry_at[slot] = self._boundary + min(
                1 << n, self.swap_backoff_cap)

    def _backed_off(self, slot: int) -> bool:
        """True while `slot`'s swap backoff window is open: the
        scheduler neither retries its swap nor picks it as a victim
        (both directions share the per-slot failure counter)."""
        return self._retry_at.get(slot, 0) > self._boundary

    def _quarantine(self, slot: int, reason: str):
        """Remove a failing slot from service: free its pages (both
        tiers), requeue its request at the ADMISSION FRONT with output
        reset (greedy decode is deterministic and per-slot independent,
        so the restarted request's tokens are bit-identical to an
        uninterrupted run — the chaos harness asserts this), and clear
        every per-slot scheduler stamp. The slot's reserved worst-case
        growth is released the moment this returns — the same boundary
        (satellite 6), not at retirement."""
        req = next((r for r in self.active.values() if r.slot == slot),
                   None)
        if req is None:
            return
        self.kvm.free_seq(slot)
        del self.active[req.rid]
        self._release_slot(slot)
        req.slot = -1
        req.out = []
        req.pending_prompt = []
        self.queue.appendleft(req)
        if self.journal is not None:
            self.journal.append(jl.QUAR, {"rid": req.rid, "lanes": 0})
        self.metrics["quarantines"] += 1
        self.metrics["requeues"] += 1
        if "watchdog" in reason:
            self.metrics["watchdog_quarantines"] += 1

    def _release_slot(self, slot: int):
        """Per-slot scheduler-state cleanup shared by retirement and
        quarantine: a reused slot must not inherit its predecessor's
        backoff window, watchdog stamp or residency ages."""
        self.ctx_lens[slot] = 0
        for d in (self._pending_since, self._resident_since,
                  self._swap_fails, self._retry_at, self._progress):
            d.pop(slot, None)

    def _watchdog(self):
        """Macro-boundary watchdog: force-quarantine any lane with no
        progress for ``watchdog_rounds`` boundaries — the backstop that
        catches a lane stuck behind a pathologically browned-out
        channel or an unlucky fault schedule, so the rest of the batch
        keeps its throughput. Progress is token progress (generated
        output or consumed prompt chunk) OR a completed tier move (the
        swap paths clear the stamp): a host-resident lane rotating
        through the normal oversubscription cycle is WAITING, not
        wedged, and must not be restarted — only a lane that neither
        decodes nor moves for the whole window is."""
        for r in list(self.active.values()):
            s = r.slot
            cur = (len(r.out), len(r.pending_prompt))
            last = self._progress.get(s)
            if last is None or (last[0], last[1]) != cur:
                self._progress[s] = (cur[0], cur[1], self._boundary)
            elif self._boundary - last[2] >= self.watchdog_rounds:
                self._quarantine(s, "watchdog: no token progress")

    def _stall_shrink(self, free: np.ndarray) -> np.ndarray:
        """Apply the fault plane's per-channel stall multipliers to a
        free-block vector: a browned-out channel advertises 1/stall of
        its blocks. Identity without a plane."""
        if self.faults is not None:
            st = self.faults.stall_vec(self.channels)
            if (st > 1.0).any():
                free = (free / np.maximum(st, 1.0)).astype(np.int64)
        return free

    def _free_eff(self) -> np.ndarray:
        """Per-channel free device blocks as advertised to the boundary
        planners (_macro_eligible + _swap_schedule), shrunk by the
        fault plane's stall multipliers: a browned-out channel offers
        1/stall of its free blocks, so residency/growth shrink THERE
        while healthy channels keep full budget — graceful degradation
        through the existing per-channel eligibility vectors rather
        than a new scheduler. Identical to kvm.free_device_vec()
        without a plane. The single-step fallback path deliberately
        ignores stall (it allocates against the real pool), so a
        brownout can never livelock the engine — it only slows it."""
        return self._stall_shrink(self.kvm.free_device_vec())

    # ----------------------------------------- GC boundary walk (ISSUE 9)
    def _gc_boundary(self):
        """Watermark-triggered victim eviction (the paper's GCM): when
        some channel's free device blocks drop below ``gc.watermark``,
        run one budgeted walk — pick each pressured channel's
        fragmented erase block with the fewest live pages (from the
        counts the fused commits already maintain), relocate its live
        pages as ONE batched CondUpdate + KV row move, and free the
        whole victim. Budgeted (``gc.pages_per_boundary``) so GC can
        never stall decode; journaled as a host commit so a crash
        mid-walk recovers bit-identically."""
        gc = self.gc
        if bool((self.kvm.free_device_vec() >= gc.watermark).all()):
            return
        pools = [self.caches["pool_k"], self.caches["pool_v"]]
        pools, moved, victims = self.kvm.gc_collect(
            pools, block_axis=2, block_pages=gc.block_pages,
            budget=gc.pages_per_boundary)
        self.caches["pool_k"], self.caches["pool_v"] = pools
        self.metrics["gc_walks"] += 1
        self.metrics["gc_moves"] += moved
        self.metrics["gc_victims"] += victims

    def _swap_schedule(self):
        """Boundary swap planner (DESIGN.md "Non-blocking host-tier
        swap pipeline"): runs between macro-steps and keeps the fused
        scan eligible — swap-pending slots become masked lanes instead
        of dropping the engine to single-step mode. Three passes:

          1. reserve — swap out victims (longest context first, like
             ``_preempt``) until the residents' worst-case K-step
             growth fits the free device pool;
          2. resume — swap waiting slots back in, FIFO by the boundary
             they were swapped out, while they fit beside the reserve;
          3. aging — a slot pending longer than ``swap_patience``
             boundaries evicts the longest-resident slots until it
             fits: starvation-free rotation under sustained
             oversubscription.

        Every move is the fused donated swap with ``check=False`` —
        the host dispatches it and keeps scheduling; nothing blocks
        until the next token readback."""
        kvm = self.kvm
        if kvm.pool.n_host == 0 or not self.active:
            return
        slots = {r.slot for r in self.active.values()}
        residents = [s for s in slots if kvm.is_resident(s)]
        pending = sorted((s for s in slots if not kvm.is_resident(s)),
                         key=lambda s: self._pending_since.get(s, 0))
        moved_now: set = set()

        # all quantities are per-channel vectors ([total] at channels=1,
        # where every comparison reduces to the old scalar one): a
        # reserve that fits in aggregate can still dry out one channel
        def growth_total(slots):
            return sum((self._growth_need_ch(s) for s in slots),
                       np.zeros(self.channels, np.int64))

        def live():     # quarantine (mid-pass) shrinks the active set
            return {r.slot for r in self.active.values()}

        def can_resume(s):
            # a swap-in pays its one-time cost (the lane's host pages)
            # in REAL free blocks; only the ongoing growth reserve is
            # judged by the stall-shrunk budget. Dividing the whole
            # budget would count each host page `stall` times over and
            # let a strong brownout wall off re-admission entirely —
            # starving big lanes into watchdog restarts. The brownout
            # should shrink residency and growth, not re-admission.
            hp = kvm.host_pages_vec(s)
            fr = kvm.free_device_vec()
            if (hp > fr).any():
                return False
            return bool((self._stall_shrink(fr - hp)
                         >= total + self._growth_need_ch(s)).all())

        # stall-degraded budget: a browned-out channel advertises fewer
        # free blocks, so the reserve swaps residency away from it and
        # admission/growth shrink there (graceful degradation)
        free = self._free_eff
        # 1. reserve: the scan must never run any channel's pool dry.
        # Backed-off slots are not victims (their swap just failed);
        # a failed swap-out that QUARANTINED its victim freed the pages
        # outright, which serves the reserve just as well.
        total = growth_total(residents)
        while (total > free()).any() and len(residents) > 1:
            cands = [s for s in residents if not self._backed_off(s)]
            if not cands:
                break
            victim = max(cands, key=lambda s: int(self.ctx_lens[s]))
            if not self._swap_out_slot(victim):
                if victim not in live():
                    residents.remove(victim)
                    total = growth_total(residents)
                    continue
                if self._backed_off(victim):
                    continue    # SwapFault: excluded next iteration
                break           # host tier full: no pass can progress
            moved_now.add(victim)
            residents.remove(victim)
            pending.append(victim)
            total = growth_total(residents)
        # 2. resume FIFO while the reserve still holds
        for s in list(pending):
            if s in moved_now or self._backed_off(s):
                continue               # no ping-pong within one boundary
            if can_resume(s):
                if self._swap_in_slot(s):
                    moved_now.add(s)
                    pending.remove(s)
                    residents.append(s)
                    total += self._growth_need_ch(s)
                elif s not in live():
                    pending.remove(s)  # failed swap-in quarantined it
        # 3. aging rotation: the oldest pending slot forces its way in
        rest = [s for s in pending
                if s not in moved_now and not self._backed_off(s)
                and s in live()]
        if rest:
            oldest = rest[0]
            waited = self._boundary - self._pending_since.get(
                oldest, self._boundary)
            if waited >= self.swap_patience:
                while not can_resume(oldest) and len(residents) > 1:
                    cands = [s for s in residents if s not in moved_now
                             and not self._backed_off(s)]
                    if not cands:
                        break
                    victim = min(cands, key=lambda s:
                                 self._resident_since.get(s, 0))
                    if not self._swap_out_slot(victim):
                        if victim not in live():
                            residents.remove(victim)
                            total = growth_total(residents)
                            continue
                        break
                    residents.remove(victim)
                    total = growth_total(residents)
                if can_resume(oldest):
                    self._swap_in_slot(oldest)

    # ------------------------------------------------------------- prefill
    def _prefill_fn(self, params, batch, caches, table_row, slot):
        logits, cols = self.m.prefill(params, batch)
        caches = _scatter_prefill(self.cfg, self.rt, caches, cols,
                                  table_row, slot)
        return logits, caches

    def _do_prefill(self, req: Request, n_chunk: Optional[int] = None):
        """Prefill the first ``n_chunk`` prompt tokens (default: all).
        A partial chunk leaves the rest on ``req.pending_prompt`` to
        stream through the decode path as forced tokens; its boundary
        prediction is discarded (the true next token is known)."""
        n_chunk = len(req.tokens) if n_chunk is None else n_chunk
        self.metrics["prefill_tokens"] += n_chunk
        toks = jnp.asarray(req.tokens[:n_chunk], jnp.int32)[None]
        batch = {"tokens": toks}
        if req.prefix_emb is not None:
            batch["prefix_emb"] = req.prefix_emb[None]
        if req.src_emb is not None:
            batch["src_emb"] = req.src_emb[None]
            batch["src_valid"] = jnp.ones(req.src_emb.shape[:1], jnp.int32)[None]
        row = self.kvm.block_tables()[req.slot]   # device slice, no sync
        logits, self.caches = self._prefill(self.params, batch, self.caches,
                                            row, req.slot)
        n_ctx = n_chunk + (req.prefix_emb.shape[0]
                           if req.prefix_emb is not None else 0)
        self.ctx_lens[req.slot] = n_ctx
        if req.src_emb is not None:
            self.src_lens[req.slot] = req.src_emb.shape[0]
        if n_chunk < len(req.tokens):
            req.pending_prompt = list(req.tokens[n_chunk:])
            self.metrics["chunked_prefills"] += 1
        else:
            self._register_prompt(req)
            tok = int(jnp.argmax(logits[0]))
            req.out.append(tok)
            self.metrics["generated"] += 1
        self.metrics["prefills"] += 1

    # ------------------------------------------------------------- decode
    def _page_bucket(self, n_need: int) -> int:
        """Smallest power-of-2 page count >= n_need (>= min_page_bucket,
        <= max_pages): the static live-page width attention runs over.
        Raise ``min_page_bucket`` to pre-pin the bucket for an expected
        context length — every bucket crossing re-traces the decode
        jits, so latency-sensitive runs pay compilation up front."""
        p = self.min_page_bucket
        while p < n_need and p < self.max_pages:
            p *= 2
        return min(p, self.max_pages)

    def _table_grid(self, table, pages):
        """Flat (or [C, L] channel-sharded) incremental table ->
        [n_slots, <=pages] global grid: ``fb.interleave_table`` (the
        one home of the shard-interleave layout — under a mesh the
        transpose IS the boundary all-gather of the tentpole) plus the
        live-page bucket slice. Every decode path (_decode_fn,
        _macro_fn, _macro_sharded_fn) must read the table through here
        or bit-identity across paths breaks."""
        n = self.n_slots * self.max_pages    # table is geometry-padded
        grid = fb.interleave_table(table, n).reshape(self.n_slots,
                                                     self.max_pages)
        return grid[:, :pages or self.max_pages]

    def _mask_tables(self, grid, live):
        """Mask dead lanes to the scratch block (their garbage KV write
        lands there) and clamp out-of-range entries (NIL / host-tier
        tags) — the ONE shared clamp; see _table_grid."""
        t = jnp.where(live[:, None], grid, self.scratch_block)
        return jnp.where((t < 0) | (t >= self.scratch_block),
                         self.scratch_block, t)

    def _decode_fn(self, params, tokens, caches, ctx_lens, table,
                   resident_mask, src_valid=None, pages=None):
        """Single-fused serving map step: the flat device-resident table
        is reshaped and sliced to the live-page bucket (attention never
        touches pages beyond any mapped context), paused/inactive slots
        are masked to the scratch block with zeroed ctx, and
        out-of-range entries (NIL / host-tier tags) are clamped — all
        inside the decode jit, so no table bytes cross the host."""
        tables = self._mask_tables(self._table_grid(table, pages),
                                   resident_mask)
        ctx = jnp.where(resident_mask, ctx_lens, 0)
        logits, caches = self.m.decode_step(
            params, tokens, caches, ctx_lens=ctx, block_table=tables,
            src_valid=src_valid)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    def _grow_pages(self, residents) -> List[Request]:
        """Allocate pages for every resident crossing a page boundary:
        one batched allocation + one fused map call on the fast path.
        Returns the residents that may decode this step: preemption on
        the OutOfBlocks slow path may swap some out mid-step, and a
        slot whose growth failed outright PAUSES (decoding it with the
        new page unmapped would silently write its KV into the shared
        scratch block); it retries every step until blocks free up."""
        wants: Dict[int, int] = {}
        for r in residents:
            need = -(-int(self.ctx_lens[r.slot] + 1) // self.page)
            have = len(self.kvm.seq_pages[r.slot])
            if need > have and have < self.max_pages:
                wants[r.slot] = need - have
        if not wants:
            return residents
        try:
            self.kvm.extend_seqs(wants)
            return residents
        except OutOfBlocks:
            pass
        # slow path: grow slot-by-slot, preempting victims to host
        failed = set()
        transient = False
        for slot, n in wants.items():
            if slot not in self.kvm.seq_pages \
                    or not self.kvm.is_resident(slot):
                # became a preemption victim this step — or was
                # QUARANTINED mid-loop (a failed preempt swap can
                # quarantine any slot, including this one): its pages
                # are already freed and the request requeued
                continue
            try:
                self.kvm.extend_seq(slot, n)
            except OutOfBlocks as e:
                transient |= getattr(e, "transient", False)
                if not self._preempt(exclude=slot):
                    failed.add(slot)
                    continue
                try:
                    self.kvm.extend_seq(slot, n)
                except OutOfBlocks as e:
                    transient |= getattr(e, "transient", False)
                    failed.add(slot)
        if len(failed) == len(residents) and not transient:
            # nothing extended, nothing swapped: the same state recurs
            # next step, so pausing would livelock instead of degrade.
            # An INJECTED transient exhaustion is exempt — its schedule
            # advances every consult, so retrying next step is progress,
            # not the same state (PoolExhausted.transient, ISSUE 6)
            raise OutOfBlocks(
                f"pool exhausted: all {len(residents)} resident "
                "sequences need pages and none can be grown or "
                "preempted (no host tier / no victim)")
        # r.rid in active: a request quarantined during the loop holds a
        # freed slot — decoding it would write KV through a NIL mapping
        return [r for r in residents
                if r.slot not in failed and r.rid in self.active
                and self.kvm.is_resident(r.slot)]

    def _decode_step(self, done: Dict[int, List[int]]):
        self._ensure_resident()
        residents = [r for r in self.active.values()
                     if self.kvm.is_resident(r.slot)]
        if not residents:
            return
        residents = self._grow_pages(residents)
        if not residents:
            return
        tokens = np.zeros(self.n_slots, np.int32)
        resident_mask = np.zeros(self.n_slots, bool)
        for r in residents:
            tokens[r.slot] = (r.pending_prompt[0] if r.pending_prompt
                              else r.out[-1] if r.out else r.tokens[-1])
            resident_mask[r.slot] = True
        src_valid = self._src_valid()
        # numpy args go straight to the jit (its shard_args transfer is
        # cheaper than an explicit device_put per array); the only
        # per-step host sync is the next_tok readback
        pages = self._page_bucket(max(
            len(self.kvm.seq_pages[r.slot]) for r in residents))
        next_tok, self.caches = self._decode(
            self.params, tokens, self.caches, self.ctx_lens,
            self.kvm.state.table, resident_mask, src_valid, pages)
        self._finish_step(residents, np.asarray(next_tok), done)

    # ------------------------------------------------------ macro-steps
    def _macro_fn(self, params, ms, caches, cur_tok, ctx_lens, n_pages,
                  alive, budget, forced, src_valid=None, pages=None,
                  simple=False):
        """K fused decode steps under ONE jit (lax.scan): per step, page
        -boundary detection -> device-side block alloc + fused map
        commit (fb.serving_grow) -> masked decode -> greedy sample ->
        retire slots that hit EOS or their max_new budget. Lane masking
        matches _decode_fn exactly (scratch block, zeroed ctx, zeroed
        token) so a scan step is bit-identical to a single step.

        The alloc + translate commit runs under a lax.cond that only
        fires on steps where some lane crosses a page boundary — steady
        steps pay a bare decode plus a few fused elementwise ops, which
        is what makes K-step fusion pay on a CPU where per-op overhead
        dominates tiny graphs.

        ``simple`` (static) additionally drops the per-step retirement
        machinery: the caller guarantees no lane can finish mid-scan
        (eos_id < 0 and every budget covers the scan's emitted
        tokens), so the live set is the input ``alive`` for the whole
        scan and the masked block table only changes on growth steps
        (it rides the carry between refreshes).

        ``forced`` = (fmask [K,S], ftok [K,S], emit [K,S]): chunked
        admission streams the un-prefilled remainder of a prompt
        through the scan — where fmask, the step consumes ftok (the
        known prompt token) instead of the carried sample, and only
        steps with emit count against the max_new budget / EOS
        retirement (predictions inside the prompt are discarded by the
        host). ``forced=None`` (a separate trace, like simple/full) is
        the steady state — no lane mid-prompt — and adds ZERO ops and
        ZERO transfers to the scan: the macro hot path pays nothing
        for the admission machinery.

        The input ``alive`` mask is intersected with the device's own
        ``ms.swap_pending`` residency lane: a slot whose pages sit in
        (or are moving to) the host tier is a paused lane for the
        whole scan — every other slot keeps decoding, which is what
        makes swaps overlap decode instead of gating it.

        Returns (ms, caches, toks [K,S], oob). In full mode toks is
        NIL on lanes that emitted nothing (retired/paused); in simple
        mode dead-lane columns are garbage and the host masks them
        with its own alive vector. Either way the host replays the
        deterministic allocation sequence from the validity mask (the
        allocator mirror makes device pops predictable, so no
        allocation log needs to leave the device)."""
        g = self.kvm.geom
        page = self.page
        i32 = jnp.int32
        slots = jnp.arange(self.n_slots, dtype=i32)

        def mask_tables(ms, live):
            # shared grid + clamp (bucket slice is static): attention
            # work scales with actual context, exactly like _decode_fn
            return self._mask_tables(self._table_grid(ms.table, pages),
                                     live)

        def grow_commit(ms, npg, grow):
            # pop from the device free stack + commit dlpn->block in
            # one fused translate (single-probe invariant kept)
            dl_new = slots * self.max_pages + npg
            ms, _, ok = fb.serving_grow(g, ms, grow, dl_new)
            return ms, ok

        if simple:
            # n_pages/budget repurposed: the host precomputes the whole
            # growth schedule (it already replays the identical
            # arithmetic at the boundary) — n_pages is (grow_sched
            # [K,S] bool, grow_any [K] bool, dl_sched [K,S] int32) and
            # the scan body needs zero boundary-detection ops
            grow_sched, grow_any, dl_sched = n_pages
            xs = (grow_sched, grow_any, dl_sched)
            if forced is not None:
                xs += forced[:2]            # (fmask, ftok); emit unused
            # swap-pending slots are paused lanes for the whole scan
            alive0 = alive & ~ms.swap_pending

            def body(carry, xs):
                ms, caches, tok, ctx, tables = carry
                if forced is None:
                    gs, ga, dl = xs
                else:
                    gs, ga, dl, fm, ft = xs
                    tok = jnp.where(fm & alive0, ft, tok)

                def do_grow(ms):
                    # no lane can fail here (the host's worst-case
                    # eligibility check covers the scan), but if one
                    # does, ms.oob is raised and the host recovers
                    ms, _, _ = fb.serving_grow(g, ms, gs, dl)
                    return ms, mask_tables(ms, alive0)

                ms, tables = jax.lax.cond(
                    ga, do_grow, lambda ms: (ms, tables), ms)
                logits, caches = self.m.decode_step(
                    params, tok, caches,
                    ctx_lens=jnp.where(alive0, ctx, 0),
                    block_table=tables, src_valid=src_valid)
                nxt = jnp.argmax(logits, axis=-1).astype(i32)
                return (ms, caches, jnp.where(alive0, nxt, 0),
                        ctx + alive0.astype(i32), tables), nxt

            carry = (ms, caches, jnp.where(alive0, cur_tok, 0), ctx_lens,
                     mask_tables(ms, alive0))
            carry, toks = jax.lax.scan(body, carry, xs,
                                       length=self.macro_k)
            return carry[0], carry[1], toks, carry[0].oob

        alive = alive & ~ms.swap_pending

        def body(carry, xs):
            ms, caches, tok, ctx, npg, alive, bud = carry
            if forced is None:
                em = True
            else:
                fm, ft, em = xs
                tok = jnp.where(fm & alive, ft, tok)
            need = (ctx + page) // page          # ceil((ctx+1)/page)
            grow = alive & (need > npg) & (npg < self.max_pages)

            def do_grow(args):
                ms, npg = args
                ms, ok = grow_commit(ms, npg, grow)
                # a lane that wanted a block and failed PAUSES (it must
                # not decode into the shared scratch block); the sticky
                # oob flag sends the host to the single-step fallback
                live = alive & ~(grow & ~ok)
                return ms, npg + ok.astype(i32), live

            def no_grow(args):
                ms, npg = args
                return ms, npg, alive

            ms, npg, live = jax.lax.cond(grow.any(), do_grow, no_grow,
                                         (ms, npg))
            # decode against the incremental table, masked exactly like
            # _decode_fn (scratch block, zeroed ctx, zeroed token)
            logits, caches = self.m.decode_step(
                params, jnp.where(live, tok, 0), caches,
                ctx_lens=jnp.where(live, ctx, 0),
                block_table=mask_tables(ms, live), src_valid=src_valid)
            nxt = jnp.argmax(logits, axis=-1).astype(i32)
            # advance + retire finished lanes (EOS / budget) with pause
            # semantics: frozen ctx, no growth, no tokens. Only steps
            # that EMIT (prediction past the prompt) spend budget or
            # can retire — forced prompt steps never finish a lane.
            tok = jnp.where(live, nxt, tok)
            ctx = ctx + live.astype(i32)
            emitted = live & em
            bud = bud - emitted.astype(i32)
            fin = emitted & ((nxt == self.eos_id) | (bud <= 0))
            alive = alive & ~fin
            return (ms, caches, tok, ctx, npg, alive, bud), \
                jnp.where(live, nxt, NIL)

        carry = (ms, caches, cur_tok, ctx_lens, n_pages, alive, budget)
        carry, toks = jax.lax.scan(body, carry, forced,
                                   length=self.macro_k)
        ms, caches = carry[0], carry[1]
        return ms, caches, toks, ms.oob

    def _macro_eligible(self) -> bool:
        """Macro-steps run only when the scan provably cannot need the
        host mid-flight: the device pool covers the worst-case K-step
        growth of every decoding lane (so the in-graph allocator
        cannot run dry — pool exhaustion falls back to the single-step
        path, whose preempt/pause machinery needs the host). Finishing
        mid-scan is fine (handled in-graph). Under ``nonblocking_swap``
        a non-resident slot is NOT a fallback: it is a swap-pending
        lane, masked in the scan while everyone else decodes (the
        boundary scheduler already reserved growth headroom for the
        residents); pre-ISSUE-4 behavior required every slot
        resident."""
        if not self._macro_on or not self.active:
            return False
        need = np.zeros(self.channels, np.int64)
        n_res = 0
        for r in self.active.values():
            if not self.kvm.is_resident(r.slot):
                if not self.nonblocking_swap:
                    return False
                continue        # swap-pending lane: masked, not a fallback
            n_res += 1
            need += self._growth_need_ch(r.slot)
        # per-channel fit: a dry channel is real pool pressure even
        # while other channels still hold blocks (channels=1 reduces to
        # the old total comparison). _free_eff folds in the fault
        # plane's brownout multipliers — a stalled channel's shrunken
        # budget pushes growth pressure to the swap scheduler instead
        return n_res > 0 and bool((need <= self._free_eff()).all())

    def _src_valid(self):
        if not self.cfg.n_enc_layers:
            return None
        return (np.arange(self.src_cap)[None, :]
                < self.src_lens[:, None]).astype(np.int32)

    def _macro_lanes(self, residents, K: int):
        """Lane arrays for one K-step scan (shared by the unsharded and
        channel-sharded macro steps): tokens/alive/budget/pages plus
        the forced-lane schedule for chunk-prefilled prompts."""
        tokens = np.zeros(self.n_slots, np.int32)
        alive = np.zeros(self.n_slots, bool)
        budget = np.zeros(self.n_slots, np.int32)
        npages = np.zeros(self.n_slots, np.int32)
        pend = np.zeros(self.n_slots, np.int32)
        fmask = np.zeros((K, self.n_slots), bool)
        ftok = np.zeros((K, self.n_slots), np.int32)
        emit = np.ones((K, self.n_slots), bool)
        slot2req: Dict[int, Request] = {}
        for r in residents:
            s = r.slot
            tokens[s] = (r.pending_prompt[0] if r.pending_prompt
                         else r.out[-1] if r.out else r.tokens[-1])
            alive[s] = True
            budget[s] = r.max_new - len(r.out)
            npages[s] = len(self.kvm.seq_pages[s])
            slot2req[s] = r
            # forced lanes: steps [0, P) consume known prompt tokens;
            # predictions before step P-1 are inside the prompt and
            # neither emit nor spend budget
            p = len(r.pending_prompt)
            pend[s] = p
            if p:
                chunk = r.pending_prompt[:K]
                fmask[:len(chunk), s] = True
                ftok[:len(chunk), s] = chunk
                emit[:min(p - 1, K), s] = False
        return (tokens, alive, budget, npages, pend, fmask, ftok, emit,
                slot2req)

    def _growth_walk(self, live_of_step, npages, ctx):
        """The mirror-protocol page-boundary walk: which slots pop a
        block at each of the K scan steps. ONE home for the arithmetic
        (`need = (ctx + page) // page; grow = live & (need > npg) &
        (npg < max_pages)`) — the C=1 simple scheduler, the full-mode
        reconcile replay, and the sharded pre-commit must pop
        bit-identically or the host/device allocator mirror breaks.
        ``live_of_step(k)`` -> [S] bool mask of lanes decoding at step
        k. Returns (grow [K,S] bool, dl [K,S] int32 — each slot's next
        unmapped dlpn at that step, npg_end [S])."""
        K, S = self.macro_k, self.n_slots
        grow = np.zeros((K, S), bool)
        dl = np.zeros((K, S), np.int32)
        base = np.arange(S, dtype=np.int32) * self.max_pages
        npg = npages.copy()
        ctx = ctx.copy()
        for k in range(K):
            live = live_of_step(k)
            need = (ctx + self.page) // self.page
            grow[k] = live & (need > npg) & (npg < self.max_pages)
            dl[k] = base + npg
            npg += grow[k]
            ctx += live
        return grow, dl, npg

    def _macro_book_simple(self, residents, toks, pend, K: int,
                           done: Dict[int, List[int]]):
        """Boundary bookkeeping for a simple-mode scan: every alive
        lane ran all K steps and none can have finished mid-scan (the
        budget covered the emitted tokens; budget == emitted retires
        here at the boundary). A forced lane discards predictions
        inside its prompt: its outputs start at scan step P-1."""
        self.metrics["decode_steps"] += K
        for r in residents:
            s = r.slot
            p = int(pend[s])
            if p:
                # forced lanes are prompt work riding the decode path:
                # count them into the prefill-FLOP proxy
                self.metrics["prefill_tokens"] += min(p, K)
                del r.pending_prompt[:min(p, K)]
                if not r.pending_prompt:
                    self._register_prompt(r)   # drained mid-scan
                outs = ([int(t) for t in toks[p - 1:, s]]
                        if p <= K else [])
            else:
                outs = [int(t) for t in toks[:, s]]
            r.out.extend(outs)
            self.metrics["generated"] += len(outs)
            self.ctx_lens[s] += K
            if len(r.out) >= r.max_new:
                done[r.rid] = r.out[:r.max_new]
                self._journal_finish(r)
                self.kvm.free_seq(s)
                self._release_slot(s)
                del self.active[r.rid]

    def _macro_book_full(self, valid, toks, slot2req,
                         done: Dict[int, List[int]]):
        """Boundary bookkeeping for a full-mode scan: replay the
        emitted tokens step by step (NIL lanes emitted nothing)."""
        for k in range(valid.shape[0]):
            if not valid[k].any():
                break                  # everyone retired: steps k.. idle
            stepped = [slot2req[s] for s in range(self.n_slots)
                       if valid[k, s]]
            self._finish_step(stepped, toks[k], done)

    def _macro_decode_step(self, done: Dict[int, List[int]]):
        """Launch one K-step fused scan, then do the boundary work:
        ONE host sync (token matrix + oob flag), allocator-delta
        replay, token bookkeeping, frees."""
        if self.channels > 1:
            return self._macro_decode_step_sharded(done)
        self.kvm.sync_allocator()      # no-op unless the pool mutated
        # swap-pending slots stay active but are NOT in the batch: they
        # are masked lanes until the boundary scheduler resumes them
        residents = [r for r in self.active.values()
                     if self.kvm.is_resident(r.slot)]
        K = self.macro_k
        (tokens, alive, budget, npages, pend, fmask, ftok, emit,
         slot2req) = self._macro_lanes(residents, K)
        # CTP (ISSUE 9): the boundary knows the next K-step growth
        # exactly (the same mirror-protocol walk the scheduler and the
        # reconcile replay run), so pull the backing-table segments
        # those dlpns live in into the CMT AHEAD of the scan's
        # in-graph UPDATE commits
        if self.gc is not None and self.gc.prefetch and residents:
            pgs, pdl, _ = self._growth_walk(lambda k: alive, npages,
                                            self.ctx_lens)
            if pgs.any():
                self.kvm.prefetch_segments(pdl[pgs])
        src_valid = self._src_valid()
        # the `simple` specialization applies when no lane can finish
        # mid-scan: without EOS the retirement machinery is dead weight
        # on every scan step. A forced lane only emits K - (P-1) tokens
        # during the scan, so its budget needs to cover just that.
        gen = K - np.maximum(pend - 1, 0)
        simple = self.eos_id < 0 and bool(
            (budget[alive] >= gen[alive]).all())
        if simple:
            # precompute the growth schedule the scan will follow (no
            # retirement ⟹ the live set is static ⟹ page crossings
            # are a pure function of ctx/pages the host already holds)
            grow_sched, dl_sched, npages = self._growth_walk(
                lambda k: alive, npages, self.ctx_lens)
            sched = (grow_sched, grow_sched.any(axis=1), dl_sched)
        # live-page bucket: worst-case pages any slot can hold by scan
        # end (exact post-schedule count in simple mode)
        if simple:
            pages = self._page_bucket(int(npages[alive].max()))
        else:
            end = np.minimum(
                self.max_pages,
                np.maximum(npages, (self.ctx_lens + self.macro_k
                                    + self.page - 1) // self.page))
            pages = self._page_bucket(int(end[alive].max()))
        MACRO_DISPATCHES[0] += 1
        # steady state (no lane mid-prompt) uses the forced=None trace:
        # the scan carries zero admission machinery
        forced = (fmask, ftok, emit) if pend.any() else None
        st, self.caches, toks, oob = (
            self._macro_simple(
                self.params, self.kvm.state, self.caches, tokens,
                self.ctx_lens, sched, alive, budget, forced, src_valid,
                pages)
            if simple else
            self._macro(
                self.params, self.kvm.state, self.caches, tokens,
                self.ctx_lens, npages, alive, budget, forced, src_valid,
                pages))
        self.kvm.state = st
        HOST_SYNCS[0] += 1
        toks, oob = jax.device_get((toks, oob))
        self.metrics["macro_steps"] += 1
        if simple:
            # np.nonzero on [K,S] is row-major == the scan's step-major
            # slot-ascending pop order
            grow_seq = [int(s) for s in np.nonzero(grow_sched)[1]]
        else:
            # NIL marks lanes that emitted nothing (retired/paused);
            # replay the scan's growth decisions (the same _growth_walk
            # arithmetic, gated on the scan's own live mask) to recover
            # the allocation sequence — the allocator mirror makes the
            # popped block ids predictable, so no log left the device
            valid = (toks >= 0) & alive[None, :]
            grew, _, npages = self._growth_walk(
                lambda k: valid[k], npages, self.ctx_lens)
            grow_seq = [int(s) for s in np.nonzero(grew)[1]]
        got = self.kvm.reconcile_macro(grow_seq)
        self._retire_macro_programs(grow_seq, got)
        if simple:
            self._macro_book_simple(residents, toks, pend, K, done)
        else:
            self._macro_book_full(valid, toks, slot2req, done)
        if oob:
            # the proactive check makes this unreachable without a
            # fault plane; fold the flag into the typed per-channel
            # exhaustion counts and mark the allocator dirty (the
            # re-sync clears the lane) — single-step mode recovers
            self.kvm.observe_exhaustion(flags=[oob])

    def _retire_macro_programs(self, grow_seq, got):
        """Program-fault check for in-scan growth (ISSUE 6): the scan
        already WROTE KV into the blocks it popped, so retiring a bad
        one must also move its rows — ``retire_bad_blocks(pools=...)``
        runs the CondUpdate relocation and the old->new row copy in one
        donated jit (a bad block is just another relocation, same as
        the swap pipeline). Plane consults follow device pop order
        (step-major, slot-ascending = grow_seq order), matching the
        order the pre-commit paths consult in."""
        kvm = self.kvm
        if not got or kvm.faults is None:
            return
        idx = {s: len(kvm.seq_pages[s]) - len(bs)
               for s, bs in got.items()}
        bad = []
        for s in grow_seq:
            j = idx[s]
            idx[s] = j + 1
            if kvm.faults.program_fails():
                bad.append((s * self.max_pages + j, kvm.seq_pages[s][j]))
        if not bad:
            return
        pools = [self.caches["pool_k"], self.caches["pool_v"]]
        pools, _ = kvm.retire_bad_blocks(bad, pools=pools, block_axis=2)
        self.caches["pool_k"], self.caches["pool_v"] = pools

    # -------------------------------------- channel-sharded macro-steps
    def _macro_sharded_fn(self, params, caches, table, cur_tok,
                          ctx_lens, alive, budget, forced,
                          src_valid=None, pages=None, simple=False):
        """K decode steps against a PRE-COMMITTED channel-sharded map
        (DESIGN.md "Channel-sharded map pipeline"): the boundary
        already popped every block the scan can need and committed the
        mappings through the sharded fused translate, so the scan
        consumes a read-only table — the [C, L] shard stack
        interleaves back to global dlpn order ONCE here (on a channel
        mesh that transpose lowers to the cross-channel all-gather;
        this is the tentpole's one boundary collective). Pages mapped
        ahead of a lane's current context are invisible to attention
        (it reads ctx_lens positions only), so a scan step stays
        bit-identical to a single step. Lane masking, forced lanes and
        EOS/budget retirement mirror ``_macro_fn`` exactly; there is
        no in-graph allocator and no oob flag — per-channel pool
        pressure was resolved by the eligibility check before
        dispatch."""
        i32 = jnp.int32
        tbl = self._table_grid(table, pages)    # interleave ONCE

        def mask_tables(live):
            return self._mask_tables(tbl, live)

        if simple:
            alive0 = alive
            tables = mask_tables(alive0)
            xs = forced[:2] if forced is not None else None

            def body(carry, xs):
                caches, tok, ctx = carry
                if forced is not None:
                    fm, ft = xs
                    tok = jnp.where(fm & alive0, ft, tok)
                logits, caches = self.m.decode_step(
                    params, tok, caches,
                    ctx_lens=jnp.where(alive0, ctx, 0),
                    block_table=tables, src_valid=src_valid)
                nxt = jnp.argmax(logits, axis=-1).astype(i32)
                return (caches, jnp.where(alive0, nxt, 0),
                        ctx + alive0.astype(i32)), nxt

            carry, toks = jax.lax.scan(
                body, (caches, jnp.where(alive0, cur_tok, 0), ctx_lens),
                xs, length=self.macro_k)
            return carry[0], toks

        def body(carry, xs):
            caches, tok, ctx, alive, bud = carry
            if forced is None:
                em = True
            else:
                fm, ft, em = xs
                tok = jnp.where(fm & alive, ft, tok)
            live = alive
            logits, caches = self.m.decode_step(
                params, jnp.where(live, tok, 0), caches,
                ctx_lens=jnp.where(live, ctx, 0),
                block_table=mask_tables(live), src_valid=src_valid)
            nxt = jnp.argmax(logits, axis=-1).astype(i32)
            tok = jnp.where(live, nxt, tok)
            ctx = ctx + live.astype(i32)
            emitted = live & em
            bud = bud - emitted.astype(i32)
            fin = emitted & ((nxt == self.eos_id) | (bud <= 0))
            alive = alive & ~fin
            return (caches, tok, ctx, alive, bud), \
                jnp.where(live, nxt, NIL)

        carry, toks = jax.lax.scan(
            body, (caches, cur_tok, ctx_lens, alive, budget), forced,
            length=self.macro_k)
        return carry[0], toks

    def _macro_decode_step_sharded(self, done: Dict[int, List[int]]):
        """Channel-sharded boundary step: commit the scan's WORST-CASE
        growth schedule ahead of time — one channel-aware pool
        allocation in the scan's pop order (step-major,
        slot-ascending: exactly what K single steps would pop) + ONE
        fused sharded map dispatch (``KVPageManager.precommit_growth``)
        — then run the pure-decode K-step scan and the usual token
        bookkeeping. Per K tokens: 1 MACRO_DISPATCHES, 1 HOST_SYNCS,
        at most 1 XLATE_CALLS (growth boundaries only), 0 ALLOC_SYNCS
        (the device free stacks are not consumed in-graph; they lazily
        mirror for tests). A lane that retires mid-scan (full mode)
        keeps its pre-committed pages until the slot frees — the pool
        order then differs from the single-step schedule, which is the
        one sharding-vs-single divergence (tokens never differ)."""
        residents = [r for r in self.active.values()
                     if self.kvm.is_resident(r.slot)]
        K = self.macro_k
        (tokens, alive, budget, npages, pend, fmask, ftok, emit,
         slot2req) = self._macro_lanes(residents, K)
        # worst-case growth schedule, no-retirement arithmetic — the
        # same _growth_walk the C=1 simple scheduler and the reconcile
        # replay use (mirror protocol, one home); the walk's own dl
        # schedule rides along so pre-commit maps exactly those pages
        grow_sched, dl_walk, npg = self._growth_walk(
            lambda k: alive, npages, self.ctx_lens)
        grow_seq = [int(s) for s in np.nonzero(grow_sched)[1]]
        # CTP (ISSUE 9): warm the CMT with the backing segments the
        # pre-commit's own UPDATE batch is about to touch — the walk's
        # dl schedule IS the exact dlpn set, no prediction needed
        if self.gc is not None and self.gc.prefetch \
                and grow_sched.any():
            self.kvm.prefetch_segments(dl_walk[grow_sched])
        try:
            self.kvm.precommit_growth(
                grow_seq, dlpns=[int(d) for d in dl_walk[grow_sched]])
        except OutOfBlocks:
            # precommit raises BEFORE any pop or map write, so nothing
            # needs unwinding: an injected transient exhaustion (or a
            # pool raced dry between eligibility and here) falls back
            # to one single step; the macro path retries next boundary
            self.metrics["macro_fallbacks"] += 1
            self._decode_step(done)
            return
        src_valid = self._src_valid()
        gen = K - np.maximum(pend - 1, 0)
        simple = self.eos_id < 0 and bool(
            (budget[alive] >= gen[alive]).all())
        pages = self._page_bucket(int(npg[alive].max()))
        MACRO_DISPATCHES[0] += 1
        forced = (fmask, ftok, emit) if pend.any() else None
        if simple:
            self.caches, toks = self._macro_sh_simple(
                self.params, self.caches, self.kvm.state.table, tokens,
                self.ctx_lens, alive, budget, forced, src_valid, pages)
        else:
            self.caches, toks = self._macro_sh(
                self.params, self.caches, self.kvm.state.table, tokens,
                self.ctx_lens, alive, budget, forced, src_valid, pages)
        HOST_SYNCS[0] += 1
        toks = jax.device_get(toks)
        self.metrics["macro_steps"] += 1
        if simple:
            self._macro_book_simple(residents, toks, pend, K, done)
        else:
            valid = (toks >= 0) & alive[None, :]
            self._macro_book_full(valid, toks, slot2req, done)

    def _finish_step(self, residents, next_tok: np.ndarray,
                     done: Dict[int, List[int]]):
        self.metrics["decode_steps"] += 1
        for r in list(residents):
            self.ctx_lens[r.slot] += 1
            if r.pending_prompt:
                # forced lane: the step consumed a known prompt token;
                # its prediction only counts once the prompt is done
                self.metrics["prefill_tokens"] += 1
                r.pending_prompt.pop(0)
                if r.pending_prompt:
                    continue
                self._register_prompt(r)   # prompt drained this step
            tok = int(next_tok[r.slot])
            r.out.append(tok)
            self.metrics["generated"] += 1
            if len(r.out) >= r.max_new or tok == self.eos_id:
                done[r.rid] = r.out[:r.max_new]
                self._journal_finish(r)
                self.kvm.free_seq(r.slot)
                self._release_slot(r.slot)
                del self.active[r.rid]


# ----------------------------------------------------------------------
def _scatter_prefill(cfg: ArchConfig, rt: Runtime, caches, cols, table_row,
                     slot):
    """Write one request's prefill caches (B=1) into the slot grid.
    cols: per-period list of dicts with leaves stacked [NP, ...]."""
    period = cfg.period
    attn_js = [j for j in range(period) if cfg.layer_kind(j) == "attn"]
    ssm_js = [j for j in range(period) if cfg.layer_kind(j) == "mamba"]
    a_of = {j: i for i, j in enumerate(attn_js)}
    s_of = {j: i for i, j in enumerate(ssm_js)}
    page = rt.page_size
    caches = dict(caches)
    for j in range(period):
        col = cols[j]
        if "kv" in col:
            k, v = col["kv"]                  # [NP, 1, S, KV, hd]
            np_, _, s, kvh, hd = k.shape
            npages = -(-s // page)
            pad = npages * page - s
            kp = jnp.pad(k[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            kp = kp.reshape(np_, npages, page, kvh, hd)
            vp = vp.reshape(np_, npages, page, kvh, hd)
            rows = table_row[:npages]
            ai = a_of[j]
            # scatter: pool [NP, A, NB, P, KV, hd]
            caches["pool_k"] = caches["pool_k"].at[:, ai, rows].set(
                kp.astype(caches["pool_k"].dtype).transpose(0, 1, 2, 3, 4),
                mode="drop")
            caches["pool_v"] = caches["pool_v"].at[:, ai, rows].set(
                vp.astype(caches["pool_v"].dtype), mode="drop")
        if "ssm" in col:
            conv, ssm_st = col["ssm"]         # [NP,1,k,C], [NP,1,nh,hd,N]
            si = s_of[j]
            caches["conv"] = caches["conv"].at[:, si, slot].set(
                conv[:, 0].astype(caches["conv"].dtype))
            caches["ssm"] = caches["ssm"].at[:, si, slot].set(ssm_st[:, 0])
        if "cross_kv" in col:
            ck, cv = col["cross_kv"]          # [NP,1,Ss,KV,hd]
            cap = caches["cross_k"].shape[3]
            pad = cap - ck.shape[2]
            ckp = jnp.pad(ck[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            cvp = jnp.pad(cv[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            caches["cross_k"] = caches["cross_k"].at[:, j, slot].set(
                ckp.astype(caches["cross_k"].dtype))
            caches["cross_v"] = caches["cross_v"].at[:, j, slot].set(
                cvp.astype(caches["cross_v"].dtype))
    return caches
