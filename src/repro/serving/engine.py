"""Serving engine: continuous batching over a fixed slot grid, with the
FMMU page manager owning logical->physical KV translation.

Prefill writes each request's KV into pool blocks named by the FMMU
block table; decode steps run the whole slot batch through
Model.decode_step against the **device-resident incremental block
table** (a member of the FMMU state pytree, kept coherent by the same
fused call that commits each map write — see DESIGN.md). The decode
hot loop performs zero full-map retranslations and at most one fused
map call per step: page growth for all slots crossing a page boundary
is batched into ONE allocation + ONE ``_xlate``, and paused/invalid
slot masking happens inside the decode jit (no host table roundtrip;
the only per-step host sync is the next-token transfer). Pool
exhaustion preempts the longest victim sequence to the host tier
(swap_out, CondUpdate-guarded) — the serving analogue of the paper's
GC path.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.common import Runtime
from repro.models.model import Model, _src_len
from repro.paging.kv_manager import KVPageManager
from repro.paging.pool import OutOfBlocks


@dataclasses.dataclass
class Request:
    rid: int
    tokens: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    src_emb: Optional[jnp.ndarray] = None
    prefix_emb: Optional[jnp.ndarray] = None


class ServeEngine:
    def __init__(self, model: Model, params, *, n_slots: int,
                 max_ctx: int, n_device_blocks: Optional[int] = None,
                 n_host_blocks: int = 0, eos_id: int = -1):
        self.m = model
        self.cfg = model.cfg
        self.rt = model.rt
        self.params = params
        self.n_slots = n_slots
        self.page = self.rt.page_size
        self.max_pages = -(-max_ctx // self.page)
        n_dev = n_device_blocks or (n_slots * self.max_pages)
        self.kvm = KVPageManager(n_slots, self.max_pages, n_dev,
                                 n_host_blocks)
        src_len = _src_len(self.cfg, max_ctx)
        # +1 scratch block: unmapped table entries (inactive slots) write
        # their garbage KV there instead of corrupting block 0
        self.scratch_block = n_dev + n_host_blocks
        self.caches = transformer.init_decode_caches(
            self.cfg, self.rt, n_slots, self.max_pages,
            n_dev + n_host_blocks + 1, self.rt.compute_dtype,
            src_len=src_len)
        # int32 end-to-end: the decode jit consumes these every step and
        # an int64 numpy array would pay a device-side convert per call
        self.ctx_lens = np.zeros(n_slots, np.int32)
        self.src_cap = src_len
        self.src_lens = np.zeros(n_slots, np.int32)
        self.active: Dict[int, Request] = {}
        self.eos_id = eos_id
        self.queue: Deque[Request] = deque()
        self._rid = 0
        # caches (arg 2) are DONATED: the KV pool is updated in place
        # instead of functionally copied every step. Callers always
        # rebind self.caches from the return (same contract as the
        # donated FMMU state pytree).
        self._decode = jax.jit(self._decode_fn, donate_argnums=(2,))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(2,))
        self.metrics = {"prefills": 0, "decode_steps": 0, "preemptions": 0,
                        "generated": 0}

    # ------------------------------------------------------------- API
    def submit(self, tokens: List[int], max_new: int = 16, *,
               src_emb=None, prefix_emb=None) -> int:
        rid = self._rid
        self._rid += 1
        self.queue.append(Request(rid, list(tokens), max_new,
                                  src_emb=src_emb, prefix_emb=prefix_emb))
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        done: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            if not self.step(done):
                break
        return done

    # ------------------------------------------------------------- steps
    def step(self, done: Dict[int, List[int]]) -> bool:
        self._admit()
        if not self.active:
            return bool(self.queue)
        self._decode_step(done)
        return bool(self.active or self.queue)

    def _free_slots(self) -> List[int]:
        used = {r.slot for r in self.active.values()}
        return [s for s in range(self.n_slots) if s not in used]

    def _admit(self):
        if not self.queue:
            return
        free = self._free_slots()
        while self.queue and free:
            req = self.queue[0]
            slot = free[0]
            # on-demand allocation: admission reserves only the prompt
            # (+prefix) pages that prefill actually writes; decode grows
            # the mapping page-by-page (batched, one fused map call per
            # step) instead of parking max_new worth of blocks up front
            n_prefix = (req.prefix_emb.shape[0]
                        if req.prefix_emb is not None else 0)
            n_pages = -(-(len(req.tokens) + n_prefix) // self.page)
            n_pages = max(1, min(n_pages, self.max_pages))
            try:
                self.kvm.new_seq(slot, n_pages)
            except OutOfBlocks:
                if not self._preempt(exclude=slot):
                    return
                continue
            self.queue.popleft()
            free.pop(0)
            req.slot = slot
            self.active[req.rid] = req
            self._do_prefill(req)

    def _preempt(self, exclude: int) -> bool:
        """Swap the longest active sequence that still holds device
        pages out to the host tier (an already-swapped victim would
        move nothing). False when no such victim exists or the host
        tier itself cannot take the blocks."""
        if self.kvm.pool.n_host == 0:
            return False
        victims = [r for r in self.active.values()
                   if r.slot != exclude
                   and self.kvm.n_device_pages(r.slot) > 0]
        for victim in sorted(victims, key=lambda r: self.ctx_lens[r.slot],
                             reverse=True):
            pools = [self.caches["pool_k"], self.caches["pool_v"]]
            try:
                pools, moved = self.kvm.swap_out(victim.slot, pools,
                                                 block_axis=2)
            except OutOfBlocks:
                continue    # doesn't fit the host tier; try a smaller one
            self.caches["pool_k"], self.caches["pool_v"] = pools
            if moved:
                self.metrics["preemptions"] += 1
                return True
        return False

    def _ensure_resident(self):
        """Swap in any host-tier pages of active sequences (before decode).
        Sequences that cannot come back yet PAUSE (they are excluded from
        the decode batch) until device blocks free up. Tier predicate:
        KVPageManager.is_resident (BlockPool.is_host underneath)."""
        if self.kvm.pool.n_host == 0:
            return    # no host tier: nothing can ever be swapped out
        for r in sorted(self.active.values(),
                        key=lambda r: len(self.kvm.seq_pages.get(r.slot, []))):
            if not self.kvm.is_resident(r.slot):
                try:
                    pools = [self.caches["pool_k"], self.caches["pool_v"]]
                    pools, _ = self.kvm.swap_in(r.slot, pools,
                                                block_axis=2)
                    self.caches["pool_k"], self.caches["pool_v"] = pools
                except OutOfBlocks:
                    pass  # stays swapped & paused; retried next round

    # ------------------------------------------------------------- prefill
    def _prefill_fn(self, params, batch, caches, table_row, slot):
        logits, cols = self.m.prefill(params, batch)
        caches = _scatter_prefill(self.cfg, self.rt, caches, cols,
                                  table_row, slot)
        return logits, caches

    def _do_prefill(self, req: Request):
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        batch = {"tokens": toks}
        if req.prefix_emb is not None:
            batch["prefix_emb"] = req.prefix_emb[None]
        if req.src_emb is not None:
            batch["src_emb"] = req.src_emb[None]
            batch["src_valid"] = jnp.ones(req.src_emb.shape[:1], jnp.int32)[None]
        row = self.kvm.block_tables()[req.slot]   # device slice, no sync
        logits, self.caches = self._prefill(self.params, batch, self.caches,
                                            row, req.slot)
        n_ctx = len(req.tokens) + (req.prefix_emb.shape[0]
                                   if req.prefix_emb is not None else 0)
        self.ctx_lens[req.slot] = n_ctx
        if req.src_emb is not None:
            self.src_lens[req.slot] = req.src_emb.shape[0]
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self.metrics["prefills"] += 1
        self.metrics["generated"] += 1

    # ------------------------------------------------------------- decode
    def _decode_fn(self, params, tokens, caches, ctx_lens, table,
                   resident_mask, src_valid=None):
        """Single-fused serving map step: the flat device-resident table
        is reshaped, paused/inactive slots are masked to the scratch
        block (their garbage KV write lands there) with zeroed ctx, and
        out-of-range entries (NIL / host-tier tags) are clamped — all
        inside the decode jit, so no table bytes cross the host."""
        n = self.n_slots * self.max_pages    # table is geometry-padded
        tables = table[:n].reshape(self.n_slots, self.max_pages)
        tables = jnp.where(resident_mask[:, None], tables,
                           self.scratch_block)
        tables = jnp.where((tables < 0) | (tables >= self.scratch_block),
                           self.scratch_block, tables)
        ctx = jnp.where(resident_mask, ctx_lens, 0)
        logits, caches = self.m.decode_step(
            params, tokens, caches, ctx_lens=ctx, block_table=tables,
            src_valid=src_valid)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    def _grow_pages(self, residents) -> List[Request]:
        """Allocate pages for every resident crossing a page boundary:
        one batched allocation + one fused map call on the fast path.
        Returns the residents that may decode this step: preemption on
        the OutOfBlocks slow path may swap some out mid-step, and a
        slot whose growth failed outright PAUSES (decoding it with the
        new page unmapped would silently write its KV into the shared
        scratch block); it retries every step until blocks free up."""
        wants: Dict[int, int] = {}
        for r in residents:
            need = -(-int(self.ctx_lens[r.slot] + 1) // self.page)
            have = len(self.kvm.seq_pages[r.slot])
            if need > have and have < self.max_pages:
                wants[r.slot] = need - have
        if not wants:
            return residents
        try:
            self.kvm.extend_seqs(wants)
            return residents
        except OutOfBlocks:
            pass
        # slow path: grow slot-by-slot, preempting victims to host
        failed = set()
        for slot, n in wants.items():
            if not self.kvm.is_resident(slot):
                continue    # became a preemption victim this step
            try:
                self.kvm.extend_seq(slot, n)
            except OutOfBlocks:
                if not self._preempt(exclude=slot):
                    failed.add(slot)
                    continue
                try:
                    self.kvm.extend_seq(slot, n)
                except OutOfBlocks:
                    failed.add(slot)
        if len(failed) == len(residents):
            # nothing extended, nothing swapped: the same state recurs
            # next step, so pausing would livelock instead of degrade
            raise OutOfBlocks(
                f"pool exhausted: all {len(residents)} resident "
                "sequences need pages and none can be grown or "
                "preempted (no host tier / no victim)")
        return [r for r in residents
                if r.slot not in failed and self.kvm.is_resident(r.slot)]

    def _decode_step(self, done: Dict[int, List[int]]):
        self._ensure_resident()
        residents = [r for r in self.active.values()
                     if self.kvm.is_resident(r.slot)]
        if not residents:
            return
        residents = self._grow_pages(residents)
        if not residents:
            return
        tokens = np.zeros(self.n_slots, np.int32)
        resident_mask = np.zeros(self.n_slots, bool)
        for r in residents:
            tokens[r.slot] = r.out[-1] if r.out else r.tokens[-1]
            resident_mask[r.slot] = True
        src_valid = None
        if self.cfg.n_enc_layers:
            src_valid = (np.arange(self.src_cap)[None, :]
                         < self.src_lens[:, None]).astype(np.int32)
        # numpy args go straight to the jit (its shard_args transfer is
        # cheaper than an explicit device_put per array); the only
        # per-step host sync is the next_tok readback
        next_tok, self.caches = self._decode(
            self.params, tokens, self.caches, self.ctx_lens,
            self.kvm.state.table, resident_mask, src_valid)
        self._finish_step(residents, np.asarray(next_tok), done)

    def _finish_step(self, residents, next_tok: np.ndarray,
                     done: Dict[int, List[int]]):
        self.metrics["decode_steps"] += 1
        for r in list(residents):
            self.ctx_lens[r.slot] += 1
            tok = int(next_tok[r.slot])
            r.out.append(tok)
            self.metrics["generated"] += 1
            if len(r.out) >= r.max_new or tok == self.eos_id:
                done[r.rid] = r.out[:r.max_new]
                self.kvm.free_seq(r.slot)
                self.ctx_lens[r.slot] = 0
                del self.active[r.rid]


# ----------------------------------------------------------------------
def _scatter_prefill(cfg: ArchConfig, rt: Runtime, caches, cols, table_row,
                     slot):
    """Write one request's prefill caches (B=1) into the slot grid.
    cols: per-period list of dicts with leaves stacked [NP, ...]."""
    period = cfg.period
    attn_js = [j for j in range(period) if cfg.layer_kind(j) == "attn"]
    ssm_js = [j for j in range(period) if cfg.layer_kind(j) == "mamba"]
    a_of = {j: i for i, j in enumerate(attn_js)}
    s_of = {j: i for i, j in enumerate(ssm_js)}
    page = rt.page_size
    caches = dict(caches)
    for j in range(period):
        col = cols[j]
        if "kv" in col:
            k, v = col["kv"]                  # [NP, 1, S, KV, hd]
            np_, _, s, kvh, hd = k.shape
            npages = -(-s // page)
            pad = npages * page - s
            kp = jnp.pad(k[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            kp = kp.reshape(np_, npages, page, kvh, hd)
            vp = vp.reshape(np_, npages, page, kvh, hd)
            rows = table_row[:npages]
            ai = a_of[j]
            # scatter: pool [NP, A, NB, P, KV, hd]
            caches["pool_k"] = caches["pool_k"].at[:, ai, rows].set(
                kp.astype(caches["pool_k"].dtype).transpose(0, 1, 2, 3, 4),
                mode="drop")
            caches["pool_v"] = caches["pool_v"].at[:, ai, rows].set(
                vp.astype(caches["pool_v"].dtype), mode="drop")
        if "ssm" in col:
            conv, ssm_st = col["ssm"]         # [NP,1,k,C], [NP,1,nh,hd,N]
            si = s_of[j]
            caches["conv"] = caches["conv"].at[:, si, slot].set(
                conv[:, 0].astype(caches["conv"].dtype))
            caches["ssm"] = caches["ssm"].at[:, si, slot].set(ssm_st[:, 0])
        if "cross_kv" in col:
            ck, cv = col["cross_kv"]          # [NP,1,Ss,KV,hd]
            cap = caches["cross_k"].shape[3]
            pad = cap - ck.shape[2]
            ckp = jnp.pad(ck[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            cvp = jnp.pad(cv[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            caches["cross_k"] = caches["cross_k"].at[:, j, slot].set(
                ckp.astype(caches["cross_k"].dtype))
            caches["cross_v"] = caches["cross_v"].at[:, j, slot].set(
                cvp.astype(caches["cross_v"].dtype))
    return caches
