"""Typed serving-engine configuration (ISSUE 9 API redesign).

``ServeEngine`` accumulated fifteen keyword arguments across eight PRs
— capacity, scheduling, sharding, fault policy, durability — and PR 9
adds a GC plane on top. This module groups them into frozen dataclasses
so a serving setup is a VALUE: comparable, printable, defaultable, and
extendable without another positional-soup constructor.

    ServeEngine(model, params, config=ServeConfig(
        n_slots=8, max_ctx=256, macro_k=4,
        gc=GCConfig(watermark=2, pages_per_boundary=8)))

The legacy keyword style (``ServeEngine(model, params, n_slots=8, ...)``)
still works through :meth:`ServeConfig.from_legacy` — the engine shim
emits ONE ``DeprecationWarning`` per construction and the result is
bit-equivalent to the config form (tests/test_gc.py asserts it).
Frozen-ness is deliberate: engines snapshot their config at
construction, so mutating a config after the fact must be impossible
rather than silently ignored.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class GCConfig:
    """The GC/CTP plane (this PR's tentpole). ``None`` on ServeConfig
    disables it entirely — the map carries no live lane and every
    traced graph is bit-identical to the pre-GC engine.

    watermark: trigger a victim walk when any channel's free device
        blocks drop below this.
    pages_per_boundary: relocation budget per walk — GC never blocks
        decode for more than this many batched CondUpdate lanes.
    block_pages: pages per modeled erase block (the reclaim
        granularity; BlockPool.erase_blocks groups frames by it).
    prefetch: arm the CTP — prefetch the backing-table segments the
        next scan's pre-committed growth will touch into the CMT.
    """
    watermark: int = 2
    pages_per_boundary: int = 8
    block_pages: int = 4
    prefetch: bool = False

    def __post_init__(self):
        assert self.watermark >= 1, self.watermark
        assert self.pages_per_boundary >= 1, self.pages_per_boundary
        assert self.block_pages >= 1, self.block_pages


@dataclasses.dataclass(frozen=True)
class PrefixConfig:
    """Copy-on-write prefix sharing (ISSUE 10 tentpole). ``None`` on
    ServeConfig disables it entirely — the map carries no refcnt lane
    and every traced graph is bit-identical to the pre-sharing engine
    (string-compared in tests/test_prefix.py).

    Admission hashes each full page of a request's prompt tokens into
    a radix (prefix-tree) path; a path node that already owns a
    physical block means the page's KV is already computed and
    resident, so the new slot maps its dlpn at the SAME block (one
    fused UPDATE, a refcount bump, zero prefill FLOPs for that page).
    A slot's first divergent write to a shared page relocates it
    copy-on-write through the batched CondUpdate path.

    min_tokens: only consider sharing when the prompt carries at least
        this many tokens (short prompts aren't worth the tree walk).
    max_nodes: capacity of the host-side radix tree — LRU leaves are
        pruned (and their block references dropped) beyond it.
    """
    min_tokens: int = 16
    max_nodes: int = 4096

    def __post_init__(self):
        assert self.min_tokens >= 1, self.min_tokens
        assert self.max_nodes >= 1, self.max_nodes


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Swap-retry / watchdog policy (ISSUE 6). The fault PLANE (the
    injected schedule) stays a runtime argument — it is stateful and
    per-run — only the policy knobs live here.

    watchdog_rounds: None = the legacy default (8 * swap_patience with
        a plane attached, off without one)."""
    max_swap_retries: int = 3
    swap_backoff_cap: int = 8
    watchdog_rounds: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    """Crash-consistency journaling (ISSUE 7): attach at ``journal_path``
    (None = detached, the default) and snapshot every N-th boundary."""
    journal_path: Optional[str] = None
    snapshot_every: int = 8


# legacy ServeEngine kwarg -> (sub-config attribute path) map; flat
# kwargs not listed here live directly on ServeConfig
_LEGACY_NESTED = {
    "max_swap_retries": ("faults", "max_swap_retries"),
    "swap_backoff_cap": ("faults", "swap_backoff_cap"),
    "watchdog_rounds": ("faults", "watchdog_rounds"),
    "journal_path": ("durability", "journal_path"),
    "snapshot_every": ("durability", "snapshot_every"),
}


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything a ServeEngine needs besides the model, its params
    and the (runtime, stateful) fault plane."""
    n_slots: int
    max_ctx: int
    n_device_blocks: Optional[int] = None
    n_host_blocks: int = 0
    eos_id: int = -1
    macro_k: int = 0
    nonblocking_swap: bool = True
    admit_tokens: Optional[int] = None
    swap_patience: int = 4
    channels: int = 1
    use_mesh: Optional[bool] = None
    faults: FaultPolicy = FaultPolicy()
    durability: DurabilityConfig = DurabilityConfig()
    gc: Optional[GCConfig] = None
    prefix: Optional[PrefixConfig] = None

    @classmethod
    def from_legacy(cls, **kw) -> "ServeConfig":
        """Build a ServeConfig from the historical flat keyword set —
        the engine's deprecation shim. Unknown names raise TypeError
        exactly like the old constructor would have."""
        nested: dict = {}
        flat: dict = {}
        for k, v in kw.items():
            if k in _LEGACY_NESTED:
                sub, attr = _LEGACY_NESTED[k]
                nested.setdefault(sub, {})[attr] = v
            elif k in {f.name for f in dataclasses.fields(cls)}:
                flat[k] = v
            else:
                raise TypeError(
                    f"ServeEngine got an unexpected keyword argument "
                    f"{k!r}")
        if "faults" in nested:
            flat["faults"] = FaultPolicy(**nested["faults"])
        if "durability" in nested:
            flat["durability"] = DurabilityConfig(**nested["durability"])
        return cls(**flat)
