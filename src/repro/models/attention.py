"""GQA attention: full / sliding-window / softcapped; train, prefill,
paged decode, and cross-attention paths.

Projections are kept 3D ([d, H, hd]) so head sharding is a single spec
axis; parallel/sharding.py replicates the head axis when it does not
divide the model-axis size (e.g. arctic's 56 Q heads, every kv=8 arch).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.parallel.sharding import shard_map
from repro.models import common
from repro.models.common import Runtime, apply_rope, rope_angles


def init_attention(key, cfg, dtype, *, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": common.init_dense(ks[0], d, h * hd, dtype).reshape(d, h, hd),
        "wk": common.init_dense(ks[1], d, kv * hd, dtype).reshape(d, kv, hd),
        "wv": common.init_dense(ks[2], d, kv * hd, dtype).reshape(d, kv, hd),
        "wo": common.init_dense(ks[3], h * hd, d, dtype).reshape(h, hd, d),
    }
    if cfg.qkv_bias and not cross:
        params["bq"] = jnp.zeros((h, hd), dtype)
        params["bk"] = jnp.zeros((kv, hd), dtype)
        params["bv"] = jnp.zeros((kv, hd), dtype)
    return params


def attention_specs(cfg, *, cross: bool = False):
    specs = {
        "wq": P(None, "model", None),
        "wk": P(None, "model", None),
        "wv": P(None, "model", None),
        "wo": P("model", None, None),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = P("model", None)
        specs["bk"] = P("model", None)
        specs["bv"] = P("model", None)
    return specs


# ----------------------------------------------------------------------
def _project_qkv(params, x, cfg, rt, positions, *, rope: bool = True):
    """x [B,S,d] -> q [B,S,H,hd], k,v [B,S,KV,hd] (compute dtype)."""
    cd = rt.compute_dtype
    xq = jnp.einsum("bsd,dhk->bshk", x, common.cast(params["wq"], cd))
    xk = jnp.einsum("bsd,dhk->bshk", x, common.cast(params["wk"], cd))
    xv = jnp.einsum("bsd,dhk->bshk", x, common.cast(params["wv"], cd))
    if "bq" in params:
        xq = xq + common.cast(params["bq"], cd)
        xk = xk + common.cast(params["bk"], cd)
        xv = xv + common.cast(params["bv"], cd)
    if rope and cfg.use_rope:
        cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        xq = apply_rope(xq, cos, sin)
        xk = apply_rope(xk, cos, sin)
    return xq, xk, xv


def attn_forward(params, x, cfg, rt: Runtime, *, positions, kind="global",
                 segment_ids=None, bidirectional=False,
                 return_kv=False):
    """Training / prefill self-attention. x [B,S,d] -> [B,S,d]."""
    q, k, v = _project_qkv(params, x, cfg, rt, positions)
    window = cfg.sliding_window if kind == "local" else 0
    segs = (segment_ids, segment_ids) if segment_ids is not None else None
    out = ops.flash_attention(
        q, k, v, causal=not bidirectional, window=window,
        softcap=cfg.attn_softcap, segment_ids=segs,
        bidirectional=bidirectional, impl=rt.kernel_impl,
        q_chunk=rt.q_chunk, kv_chunk=rt.kv_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, common.cast(params["wo"], rt.compute_dtype))
    if return_kv:
        return y, (k, v)
    return y


def cross_forward(params, x, kv_cache, cfg, rt: Runtime, *, src_valid=None):
    """Decoder cross-attention. kv_cache = (k,v) [B,Ssrc,KV,hd]."""
    cd = rt.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, common.cast(params["wq"], cd))
    k, v = kv_cache
    segs = None
    if src_valid is not None:
        # mask invalid source positions via segment ids (1=valid, 0=pad)
        seg_q = jnp.ones(q.shape[:2], jnp.int32)
        segs = (seg_q, src_valid.astype(jnp.int32))
    out = ops.flash_attention(q, k, v, causal=False, bidirectional=True,
                              segment_ids=segs, impl=rt.kernel_impl,
                              q_chunk=rt.q_chunk, kv_chunk=rt.kv_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, common.cast(params["wo"], cd))


def cross_kv(params, enc_out, cfg, rt: Runtime):
    """Precompute cross-attention K/V from encoder output (once)."""
    cd = rt.compute_dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, common.cast(params["wk"], cd))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, common.cast(params["wv"], cd))
    return k, v


# ----------------------------------------------------------------------
# Paged decode
# ----------------------------------------------------------------------
def write_kv_page(pool_k, pool_v, k_new, v_new, block_table, ctx_lens,
                  page_size: int):
    """Scatter one new token's K/V into the paged pool.
    k_new/v_new [B,KV,hd]; returns updated pools."""
    b = k_new.shape[0]
    logical = ctx_lens // page_size
    offs = ctx_lens % page_size
    pages = block_table[jnp.arange(b), logical]
    pool_k = pool_k.at[pages, offs].set(k_new.astype(pool_k.dtype))
    pool_v = pool_v.at[pages, offs].set(v_new.astype(pool_v.dtype))
    return pool_k, pool_v


def attn_decode_paged(params, x, cfg, rt: Runtime, *, pool_k, pool_v,
                      block_table, ctx_lens, kind="global",
                      return_stats=False):
    """One-token decode. x [B,d]; pools [NB,P,KV,hd]; returns
    (y [B,d], pool_k, pool_v) (+ (m,l) stats for cross-shard combine)."""
    positions = ctx_lens[:, None]                      # [B,1]
    q, k, v = _project_qkv(params, x[:, None, :], cfg, rt, positions)
    pool_k, pool_v = write_kv_page(pool_k, pool_v, k[:, 0], v[:, 0],
                                   block_table, ctx_lens, rt.page_size)
    window = cfg.sliding_window if kind == "local" else 0
    res = ops.paged_attention(
        q[:, 0], pool_k, pool_v, block_table, ctx_lens + 1,
        softcap=cfg.attn_softcap, window=window,
        return_stats=return_stats, impl=rt.kernel_impl,
        pages_per_chunk=rt.paged_chunk)
    if return_stats:
        out, (m, l) = res
    else:
        out = res
    y = jnp.einsum("bhk,hkd->bd", out, common.cast(params["wo"], rt.compute_dtype))
    if return_stats:
        return y, pool_k, pool_v, (m, l)
    return y, pool_k, pool_v


def attn_decode_paged_striped(params, x, cfg, rt: Runtime, ctx, *,
                              pool_k, pool_v, block_table, ctx_lens,
                              kind="global"):
    """Page-striped decode (the flash-channel analogy, DESIGN.md §2):
    pool blocks are range-partitioned across the combine axes; each shard
    attends only its owned pages (page_mask) and partial softmax results
    merge with the flash-decoding combine — the cross-shard traffic drops
    from per-position logits/values to one (o, m, l) triple per layer.

    combine axes: ('model',) when the batch shards over data (each data
    shard holds its own sequences' pages); ('data','model') for
    batch < dp_size (one giant context striped over every chip)."""
    import functools
    from repro.kernels.ref import combine_partial_attention

    b = x.shape[0]
    batch_sharded = (b % ctx.dp_size) == 0 and b >= ctx.dp_size
    # pools are range-partitioned over (data, model) always; the batch
    # -sharded case relies on the allocator placing a sequence's blocks
    # inside its data shard's range, so the softmax combine only needs to
    # cross 'model'. batch < dp replicates q and combines everywhere.
    own_axes = tuple(ctx.dp) + ("model",)
    combine_axes = ("model",) if batch_sharded else own_axes
    positions = ctx_lens[:, None]
    q, k, v = _project_qkv(params, x[:, None, :], cfg, rt, positions)
    window = cfg.sliding_window if kind == "local" else 0

    mesh = ctx.mesh

    def body(qb, kn, vn, pk, pv, table, ctxl):
        rows_local = pk.shape[0]
        lid = jnp.int32(0)
        for ax in own_axes:
            lid = lid * mesh.shape[ax] + jax.lax.axis_index(ax)
        lo = lid * rows_local
        owned = (table >= lo) & (table < lo + rows_local)
        local_table = jnp.where(owned, table - lo, 0)
        bb = qb.shape[0]
        logical = ctxl // rt.page_size
        offs = jnp.mod(ctxl, rt.page_size)
        tgt = table[jnp.arange(bb), logical]
        t_owned = (tgt >= lo) & (tgt < lo + rows_local)
        # scatter-add of (new - current), masked to owned targets: exact
        # set() for the owning shard, a literal +0 elsewhere — immune to
        # index collisions and to any OOB-mode lowering surprises.
        rows = jnp.where(t_owned, tgt - lo, 0)
        own3 = t_owned[:, None, None]
        cur_k = pk[rows, offs]
        cur_v = pv[rows, offs]
        pk = pk.at[rows, offs].add(
            jnp.where(own3, kn.astype(pk.dtype) - cur_k, 0))
        pv = pv.at[rows, offs].add(
            jnp.where(own3, vn.astype(pv.dtype) - cur_v, 0))
        o, (m, l) = ops.paged_attention(
            qb, pk, pv, local_table, ctxl + 1, softcap=cfg.attn_softcap,
            window=window, page_mask=owned, return_stats=True,
            impl=rt.kernel_impl, pages_per_chunk=rt.paged_chunk)
        outs = jax.lax.all_gather(o.astype(jnp.float32), combine_axes)
        ms = jax.lax.all_gather(m, combine_axes)
        ls = jax.lax.all_gather(l, combine_axes)
        return combine_partial_attention(outs, ms, ls).astype(qb.dtype), \
            pk, pv

    dspec = "data" if batch_sharded else None
    pool_spec = P(own_axes if len(own_axes) > 1 else own_axes[0],
                  None, None, None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dspec, None, None), P(dspec, None, None),
                  P(dspec, None, None), pool_spec, pool_spec,
                  P(dspec, None), P(dspec)),
        out_specs=(P(dspec, None, None), pool_spec, pool_spec),
        check_vma=False)
    y, pool_k, pool_v = fn(q[:, 0], k[:, 0], v[:, 0], pool_k, pool_v,
                           block_table, ctx_lens)
    y = jnp.einsum("bhk,hkd->bd", y.astype(rt.compute_dtype),
                   common.cast(params["wo"], rt.compute_dtype))
    return y, pool_k, pool_v


def attn_decode_dense(params, x, cfg, rt: Runtime, *, cache_k, cache_v,
                      ctx_lens):
    """One-token decode against a dense (non-paged) KV cache
    [B,Smax,KV,hd] — the non-FMMU baseline path."""
    b, smax = cache_k.shape[0], cache_k.shape[1]
    positions = ctx_lens[:, None]
    q, k, v = _project_qkv(params, x[:, None, :], cfg, rt, positions)
    cache_k = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
        c, n.astype(c.dtype), i, 0))(cache_k, k, ctx_lens)
    cache_v = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
        c, n.astype(c.dtype), i, 0))(cache_v, v, ctx_lens)
    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    h = q.shape[2]
    kv = kf.shape[2]
    qg = q[:, 0].astype(jnp.float32).reshape(b, kv, h // kv, -1)
    qg = qg * (1.0 / jnp.sqrt(jnp.float32(q.shape[-1])))
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kf)
    s = common.softcap(s, cfg.attn_softcap)
    valid = jnp.arange(smax)[None, :] <= ctx_lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf).reshape(b, h, -1)
    y = jnp.einsum("bhk,hkd->bd", out.astype(rt.compute_dtype),
                   common.cast(params["wo"], rt.compute_dtype))
    return y, cache_k, cache_v
