"""Top-level Model: embeddings, stacks, head, losses, prefill/decode.

``build_model(cfg, rt, ctx)`` returns a Model whose methods are pure
functions of (params, batch) — ready for jax.jit with shardings from
``Model.param_shardings()`` / ``Model.input_specs()``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import attention, common, transformer
from repro.models.common import Runtime
from repro.parallel.sharding import ParallelCtx


def _src_len(cfg: ArchConfig, seq_len: int) -> int:
    return max(128, seq_len // 4) if cfg.n_enc_layers else 0


def _prefix_len(cfg: ArchConfig, seq_len: int) -> int:
    return min(cfg.prefix_len, seq_len // 2) if cfg.prefix_len else 0


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    rt: Runtime
    ctx: ParallelCtx

    # ------------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg, dt = self.cfg, self.rt.param_dtype
        ks = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dt),
            "stack": transformer.init_stack(ks[1], cfg, dt,
                                            cross=bool(cfg.n_enc_layers)),
            "final_norm": common.init_rms_norm(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["head"] = common.init_dense(
                ks[2], cfg.d_model, cfg.vocab_size, dt)
        if cfg.n_enc_layers:
            enc_cfg = dataclasses.replace(
                cfg, n_layers=cfg.n_enc_layers, moe=None, attn_every=0,
                layer_pattern=())
            params["enc_stack"] = transformer.init_stack(ks[3], enc_cfg, dt)
            params["enc_norm"] = common.init_rms_norm(cfg.d_model, dt)
        return params

    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "embed": P("model", None),
            "stack": transformer.stack_specs(cfg, cross=bool(cfg.n_enc_layers)),
            "final_norm": P(None,),
        }
        if not cfg.tie_embeddings:
            specs["head"] = P(None, "model")
        if cfg.n_enc_layers:
            enc_cfg = dataclasses.replace(
                cfg, n_layers=cfg.n_enc_layers, moe=None, attn_every=0,
                layer_pattern=())
            specs["enc_stack"] = transformer.stack_specs(enc_cfg)
            specs["enc_norm"] = P(None,)
        return specs

    def param_shardings(self, params_or_shapes):
        return self.ctx.tree_shardings(self.specs(), params_or_shapes,
                                       fsdp=self.ctx.fsdp_params)

    def param_shapes(self, ) -> Dict[str, Any]:
        return jax.eval_shape(lambda k: self.init(k), jax.random.key(0))

    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        x = params["embed"][tokens].astype(self.rt.compute_dtype)
        if self.cfg.name.startswith("gemma"):
            x = x * jnp.asarray(math.sqrt(self.cfg.d_model), x.dtype)
        return x

    def _fuse_inputs(self, params, batch):
        """tokens (+ prefix embeddings) -> x [B,S,d], positions [B,S]."""
        x = self._embed(params, batch["tokens"])
        if "prefix_emb" in batch:
            pre = batch["prefix_emb"].astype(self.rt.compute_dtype)
            x = jnp.concatenate([pre, x], axis=1)
        b, s = x.shape[:2]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        spec = (P("data", "model", None) if self.rt.seq_shard_acts
                else P("data", None, None))
        return self.ctx.constraint(x, spec), positions

    def _encode(self, params, batch):
        if not self.cfg.n_enc_layers:
            return None, None
        enc_cfg = dataclasses.replace(
            self.cfg, n_layers=self.cfg.n_enc_layers, moe=None,
            attn_every=0, layer_pattern=())
        src = batch["src_emb"].astype(self.rt.compute_dtype)
        pos = jnp.broadcast_to(jnp.arange(src.shape[1])[None], src.shape[:2])
        enc_model = dataclasses.replace(self, cfg=enc_cfg)
        enc_out, _, _ = transformer.stack_forward(
            params["enc_stack"], src, enc_cfg, self.rt, self.ctx,
            positions=pos, bidirectional=True)
        enc_out = common.rms_norm(enc_out, params["enc_norm"], enc_cfg.norm_eps)
        return enc_out, batch.get("src_valid")

    def _logits(self, params, x):
        cd = self.rt.compute_dtype
        if self.cfg.tie_embeddings:
            logits = x @ common.cast(params["embed"], cd).T
        else:
            logits = x @ common.cast(params["head"], cd)
        return common.softcap(logits.astype(jnp.float32),
                              self.cfg.final_softcap)

    # ------------------------------------------------------------------
    def loss_fn(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Train loss. batch: tokens [B,S], labels [B,S] (-1 = masked),
        optional positions/segment_ids/prefix_emb/src_emb/src_valid."""
        x, positions = self._fuse_inputs(params, batch)
        enc_out, src_valid = self._encode(params, batch)
        x, aux, _ = transformer.stack_forward(
            params["stack"], x, self.cfg, self.rt, self.ctx,
            positions=positions, segment_ids=batch.get("segment_ids"),
            enc_out=enc_out, src_valid=src_valid)
        x = common.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        labels = batch["labels"]
        if "prefix_emb" in batch:   # loss only on the text tail
            x = x[:, -labels.shape[1]:]
        logits = self._logits(params, x)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        ntok = jnp.maximum(mask.sum(), 1.0)
        loss = nll.sum() / ntok
        metrics = {"nll": loss, "aux_loss": aux, "tokens": ntok}
        if self.rt.zloss:
            zl = self.rt.zloss * ((lse * mask) ** 2).sum() / ntok
            loss = loss + zl
            metrics["zloss"] = zl
        loss = loss + aux
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------------
    def prefill(self, params, batch):
        """Returns (last_logits [B,V], raw caches for the paging layer)."""
        x, positions = self._fuse_inputs(params, batch)
        enc_out, src_valid = self._encode(params, batch)
        x, _, caches = transformer.stack_forward(
            params["stack"], x, self.cfg, self.rt, self.ctx,
            positions=positions, enc_out=enc_out, src_valid=src_valid,
            collect_caches=True)
        x = common.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        last = x[:, -1]
        return self._logits(params, last), caches

    def decode_step(self, params, tokens, caches, *, ctx_lens, block_table,
                    src_valid=None):
        """tokens [B] -> (logits [B,V], updated caches)."""
        x = self._embed(params, tokens)
        x = self.ctx.constraint(x, P("data", None))
        x, caches = transformer.stack_decode(
            params["stack"], x, caches, self.cfg, self.rt, self.ctx,
            ctx_lens=ctx_lens, block_table=block_table, src_valid=src_valid)
        x = common.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return self._logits(params, x), caches

    # ------------------------------------------------------------------
    def cache_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        specs = {}
        if cfg.n_attn_layers:
            if self.rt.shard_kv_pool_pages:
                # long-context lever: stripe pool blocks across the
                # combine axes (the flash-channel analogy)
                b = None  # decided by batch shardability at trace time
                shape_b = None
                pool = P(None, None, ("data", "model"), None, None, None)
            else:
                pool = P(None, None, "data", None, None, "model")
            specs["pool_k"] = pool
            specs["pool_v"] = pool
        if any(cfg.layer_kind(i) == "mamba" for i in range(cfg.n_layers)):
            specs["conv"] = P(None, None, "data", None, "model")
            specs["ssm"] = P(None, None, "data", "model", None, None)
        if cfg.n_enc_layers:
            specs["cross_k"] = P(None, None, "data", None, None, "model")
            specs["cross_v"] = P(None, None, "data", None, None, "model")
        return specs

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStructs (+ logical PartitionSpecs) for one step."""
        cfg, rt = self.cfg, self.rt
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind in ("train", "prefill"):
            pre = _prefix_len(cfg, s)
            s_text = s - pre
            out = {
                "tokens": (sds((b, s_text), i32), P("data", None)),
                "positions": (sds((b, s), i32), P("data", None)),
            }
            if shape.kind == "train":
                out["labels"] = (sds((b, s_text), i32), P("data", None))
            if pre:
                out["prefix_emb"] = (
                    sds((b, pre, cfg.d_model), rt.compute_dtype),
                    P("data", None, None))
            if cfg.n_enc_layers:
                sl = _src_len(cfg, s)
                out["src_emb"] = (sds((b, sl, cfg.d_model), rt.compute_dtype),
                                  P("data", None, None))
                out["src_valid"] = (sds((b, sl), i32), P("data", None))
            return out
        # decode: one new token against a cache of length s
        max_pages = -(-s // rt.page_size)
        n_blocks = b * max_pages
        out = {
            "tokens": (sds((b,), i32), P("data")),
            "ctx_lens": (sds((b,), i32), P("data")),
            "block_table": (sds((b, max_pages), i32), P("data", None)),
        }
        caches = jax.eval_shape(
            lambda: transformer.init_decode_caches(
                cfg, rt, b, max_pages, n_blocks, rt.compute_dtype,
                src_len=_src_len(cfg, s)))
        cspecs = self.cache_specs()
        for k, v in caches.items():
            out[f"cache/{k}"] = (v, cspecs[k])
        if cfg.n_enc_layers:
            out["src_valid"] = (sds((b, _src_len(cfg, s)), i32),
                                P("data", None))
        return out


def build_model(cfg: ArchConfig, rt: Optional[Runtime] = None,
                ctx: Optional[ParallelCtx] = None) -> Model:
    from repro.parallel.sharding import trivial_ctx
    return Model(cfg=cfg, rt=rt or Runtime(), ctx=ctx or trivial_ctx())
