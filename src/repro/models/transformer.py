"""Decoder/encoder stacks: heterogeneous repeating super-blocks
(jamba's 1:7 attn:mamba + alternating MoE, gemma2's local/global pairs)
scanned with ``lax.scan`` over periods and rematerialized per policy.

Layer kinds are static per intra-period index j (cfg.period is the lcm
of all layer patterns), so one traced period body serves every period.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention, common, mlp, moe, ssm
from repro.models.common import Runtime


# ----------------------------------------------------------------------
# init / specs
# ----------------------------------------------------------------------
def _init_layer(key, cfg, j: int, dtype, *, cross: bool):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": common.init_rms_norm(cfg.d_model, dtype)}
    if cfg.layer_kind(j) == "attn":
        p["mixer"] = attention.init_attention(ks[0], cfg, dtype)
    else:
        p["mixer"] = ssm.init_ssm(ks[0], cfg, dtype)
    if cfg.post_norms:
        p["post1"] = common.init_rms_norm(cfg.d_model, dtype)
    if cross:
        p["cross_ln"] = common.init_rms_norm(cfg.d_model, dtype)
        p["cross"] = attention.init_attention(ks[1], cfg, dtype, cross=True)
    ffn: Dict[str, Any] = {}
    if cfg.is_moe_layer(j):
        ffn["moe"] = moe.init_moe(ks[2], cfg, dtype)
        if cfg.moe.dense_residual:
            ffn["dense"] = mlp.init_mlp(ks[3], cfg, dtype)
    elif cfg.d_ff:
        ffn["dense"] = mlp.init_mlp(ks[2], cfg, dtype)
    if ffn:
        p["ln2"] = common.init_rms_norm(cfg.d_model, dtype)
        p["ffn"] = ffn
        if cfg.post_norms:
            p["post2"] = common.init_rms_norm(cfg.d_model, dtype)
    return p


def _layer_specs(cfg, j: int, *, cross: bool):
    s: Dict[str, Any] = {"ln1": P(None,)}
    if cfg.layer_kind(j) == "attn":
        s["mixer"] = attention.attention_specs(cfg)
    else:
        s["mixer"] = ssm.ssm_specs(cfg)
    if cfg.post_norms:
        s["post1"] = P(None,)
    if cross:
        s["cross_ln"] = P(None,)
        s["cross"] = attention.attention_specs(cfg, cross=True)
    ffn: Dict[str, Any] = {}
    if cfg.is_moe_layer(j):
        ffn["moe"] = moe.moe_specs(cfg)
        if cfg.moe.dense_residual:
            ffn["dense"] = mlp.mlp_specs(cfg)
    elif cfg.d_ff:
        ffn["dense"] = mlp.mlp_specs(cfg)
    if ffn:
        s["ln2"] = P(None,)
        s["ffn"] = ffn
        if cfg.post_norms:
            s["post2"] = P(None,)
    return s


def init_stack(key, cfg, dtype, *, cross: bool = False):
    """Stacked params: every leaf gains a leading [n_periods] axis."""
    period = cfg.period
    n_periods = cfg.n_layers // period
    periods = []
    for pidx in range(n_periods):
        kp = jax.random.fold_in(key, pidx)
        periods.append([
            _init_layer(jax.random.fold_in(kp, j), cfg, j, dtype, cross=cross)
            for j in range(period)])
    return common.tree_stack(periods)


def stack_specs(cfg, *, cross: bool = False):
    period_specs = [_layer_specs(cfg, j, cross=cross)
                    for j in range(cfg.period)]
    return common.stacked_specs(period_specs)


# ----------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------
def _apply_layer_full(lp, x, cfg, rt: Runtime, ctx, j: int, *, positions,
                      segment_ids, bidirectional, enc_out, src_valid,
                      collect):
    """One layer, full-sequence. Returns (x, aux, collected)."""
    aux = jnp.float32(0.0)
    col: Dict[str, Any] = {}
    h = common.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.layer_kind(j) == "attn":
        if collect:
            y, (k, v) = attention.attn_forward(
                lp["mixer"], h, cfg, rt, positions=positions,
                kind=cfg.attn_kind(j), segment_ids=segment_ids,
                bidirectional=bidirectional, return_kv=True)
            col["kv"] = (k, v)
        else:
            y = attention.attn_forward(
                lp["mixer"], h, cfg, rt, positions=positions,
                kind=cfg.attn_kind(j), segment_ids=segment_ids,
                bidirectional=bidirectional)
    else:
        if collect:
            y, state = ssm.ssm_forward(lp["mixer"], h, cfg, rt,
                                       return_state=True)
            col["ssm"] = state
        else:
            y = ssm.ssm_forward(lp["mixer"], h, cfg, rt)
    if cfg.post_norms:
        y = common.rms_norm(y, lp["post1"], cfg.norm_eps)
    x = x + y
    if enc_out is not None and "cross" in lp:
        h = common.rms_norm(x, lp["cross_ln"], cfg.norm_eps)
        kv = attention.cross_kv(lp["cross"], enc_out, cfg, rt)
        y = attention.cross_forward(lp["cross"], h, kv, cfg, rt,
                                    src_valid=src_valid)
        x = x + y
        if collect:
            col["cross_kv"] = kv
    if "ffn" in lp:
        h = common.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp["ffn"]:
            y, aux = moe.apply_moe(
                lp["ffn"]["moe"], h, cfg, rt, ctx,
                dense_params=lp["ffn"].get("dense"))
        else:
            y = mlp.apply_mlp(lp["ffn"]["dense"], h, cfg, rt)
        if cfg.post_norms:
            y = common.rms_norm(y, lp["post2"], cfg.norm_eps)
        x = x + y
    return x, aux, col


def stack_forward(params, x, cfg, rt: Runtime, ctx, *, positions,
                  segment_ids=None, bidirectional=False, enc_out=None,
                  src_valid=None, collect_caches=False):
    """Full stack. Returns (x, aux_total, caches or None).

    caches (when collect_caches): pytree of per-period stacked collections
    — leaves [n_periods, ...] with a per-period list over attn/ssm layers.
    """
    period = cfg.period

    def body(carry, pp):
        xc, auxc = carry
        cols = []
        for j in range(period):
            xc, aux_j, col = _apply_layer_full(
                pp[j], xc, cfg, rt, ctx, j,
                positions=positions, segment_ids=segment_ids,
                bidirectional=bidirectional, enc_out=enc_out,
                src_valid=src_valid, collect=collect_caches)
            auxc = auxc + aux_j
            cols.append(col)
        return (xc, auxc), cols

    if rt.remat != "none":
        body = jax.checkpoint(body, policy=common.remat_policy(rt.remat),
                              prevent_cse=False)
    aux0 = jnp.float32(0.0)
    if rt.scan_layers:
        (x, aux), cols = jax.lax.scan(body, (x, aux0), params)
    else:
        n_periods = cfg.n_layers // period
        all_cols = []
        for pidx in range(n_periods):
            pp = jax.tree.map(lambda t: t[pidx], params)
            (x, aux0), cols = body((x, aux0), pp)
            all_cols.append(cols)
        aux = aux0
        cols = common.tree_stack(all_cols) if collect_caches else None
    return x, aux, (cols if collect_caches else None)


# ----------------------------------------------------------------------
# decode (one token, paged KV + recurrent states)
# ----------------------------------------------------------------------
def init_decode_caches(cfg, rt: Runtime, batch: int, max_pages_per_seq: int,
                       n_blocks: int, dtype, *, src_len: int = 0):
    """Allocate paged KV pools / SSM states, stacked [n_periods, L_kind, ...]."""
    period = cfg.period
    n_periods = cfg.n_layers // period
    attn_js = [j for j in range(period) if cfg.layer_kind(j) == "attn"]
    ssm_js = [j for j in range(period) if cfg.layer_kind(j) == "mamba"]
    caches: Dict[str, Any] = {}
    if attn_js:
        shape = (n_periods, len(attn_js), n_blocks, rt.page_size,
                 cfg.n_kv_heads, cfg.head_dim)
        caches["pool_k"] = jnp.zeros(shape, dtype)
        caches["pool_v"] = jnp.zeros(shape, dtype)
    if ssm_js:
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        nh = s.n_heads(cfg.d_model)
        caches["conv"] = jnp.zeros(
            (n_periods, len(ssm_js), batch, s.conv_dim - 1,
             di + 2 * s.d_state), dtype)
        caches["ssm"] = jnp.zeros(
            (n_periods, len(ssm_js), batch, nh, s.head_dim, s.d_state),
            jnp.float32)
    if cfg.n_enc_layers and src_len:
        caches["cross_k"] = jnp.zeros(
            (n_periods, period, batch, src_len, cfg.n_kv_heads, cfg.head_dim),
            dtype)
        caches["cross_v"] = jnp.zeros_like(caches["cross_k"])
    return caches


def stack_decode(params, x, caches, cfg, rt: Runtime, ctx, *, ctx_lens,
                 block_table, src_valid=None):
    """One decode step through the stack.
    x [B,d]; caches from init_decode_caches (pools already filled by
    prefill); block_table [B, MAXP] shared across layers."""
    period = cfg.period
    attn_js = [j for j in range(period) if cfg.layer_kind(j) == "attn"]
    ssm_js = [j for j in range(period) if cfg.layer_kind(j) == "mamba"]
    a_of = {j: i for i, j in enumerate(attn_js)}
    s_of = {j: i for i, j in enumerate(ssm_js)}

    def body(xc, scanned):
        pp, cc = scanned
        new_cc = dict(cc)
        for j in range(period):
            lp = pp[j]
            h = common.rms_norm(xc, lp["ln1"], cfg.norm_eps)
            if cfg.layer_kind(j) == "attn":
                ai = a_of[j]
                if rt.shard_kv_pool_pages:
                    y, pk, pv = attention.attn_decode_paged_striped(
                        lp["mixer"], h, cfg, rt, ctx,
                        pool_k=new_cc["pool_k"][ai],
                        pool_v=new_cc["pool_v"][ai],
                        block_table=block_table, ctx_lens=ctx_lens,
                        kind=cfg.attn_kind(j))
                else:
                    y, pk, pv = attention.attn_decode_paged(
                        lp["mixer"], h, cfg, rt,
                        pool_k=new_cc["pool_k"][ai],
                        pool_v=new_cc["pool_v"][ai],
                        block_table=block_table, ctx_lens=ctx_lens,
                        kind=cfg.attn_kind(j))
                new_cc["pool_k"] = new_cc["pool_k"].at[ai].set(pk)
                new_cc["pool_v"] = new_cc["pool_v"].at[ai].set(pv)
            else:
                si = s_of[j]
                y, (cs, ss) = ssm.ssm_decode(
                    lp["mixer"], h, (new_cc["conv"][si], new_cc["ssm"][si]),
                    cfg, rt)
                new_cc["conv"] = new_cc["conv"].at[si].set(cs)
                new_cc["ssm"] = new_cc["ssm"].at[si].set(ss)
            if cfg.post_norms:
                y = common.rms_norm(y, lp["post1"], cfg.norm_eps)
            xc = xc + y
            if "cross" in lp:
                h = common.rms_norm(xc, lp["cross_ln"], cfg.norm_eps)
                y3 = attention.cross_forward(
                    lp["cross"], h[:, None, :],
                    (cc["cross_k"][j], cc["cross_v"][j]), cfg, rt,
                    src_valid=src_valid)
                xc = xc + y3[:, 0]
            if "ffn" in lp:
                h = common.rms_norm(xc, lp["ln2"], cfg.norm_eps)
                if "moe" in lp["ffn"]:
                    y2, _ = moe.apply_moe(lp["ffn"]["moe"], h[:, None, :],
                                          cfg, rt, ctx,
                                          dense_params=lp["ffn"].get("dense"))
                    y2 = y2[:, 0]
                else:
                    y2 = mlp.apply_mlp(lp["ffn"]["dense"], h[:, None, :],
                                       cfg, rt)[:, 0]
                if cfg.post_norms:
                    y2 = common.rms_norm(y2, lp["post2"], cfg.norm_eps)
                xc = xc + y2
        return xc, new_cc

    if rt.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params, caches))
    else:
        n_periods = cfg.n_layers // period
        outs = []
        for pidx in range(n_periods):
            pp = jax.tree.map(lambda t: t[pidx], params)
            cc = jax.tree.map(lambda t: t[pidx], caches)
            x, ncc = body(x, (pp, cc))
            outs.append(ncc)
        new_caches = common.tree_stack(outs)
    return x, new_caches
