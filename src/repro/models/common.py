"""Shared model building blocks (pure functional, params = nested dicts).

Every ``init_*`` has a matching ``*_specs`` returning an identically
structured tree of ``jax.sharding.PartitionSpec`` with *logical* mesh
axis names ('data', 'model'); parallel/sharding.py resolves them onto a
concrete mesh (mapping 'data' -> ('pod','data') on the multi-pod mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution-policy knobs, orthogonal to the architecture."""
    compute_dtype: jnp.dtype = jnp.bfloat16
    param_dtype: jnp.dtype = jnp.float32
    remat: str = "dots"          # 'none' | 'dots' | 'full'
    scan_layers: bool = True
    kernel_impl: Optional[str] = None   # ops.py impl selector (None = auto)
    page_size: int = 256         # tokens per KV page
    q_chunk: int = 512
    kv_chunk: int = 1024
    # paged-attention page-chunk width (blocked lowering). None = auto:
    # one chunk whenever the whole table fits a modest live window
    # (chunking bounds live memory but costs a scan iteration of tiny
    # ops per chunk — the dominant CPU decode cost); an int pins the
    # width (benchmark baselines pin 8, the pre-ISSUE-3 default)
    paged_chunk: Optional[int] = None
    capacity_factor: Optional[float] = None
    zloss: float = 0.0
    # sharding toggles (hillclimb levers)
    shard_kv_pool_pages: bool = False  # long-context: stripe pages over data
    seq_shard_acts: bool = False       # shard sequence dim of activations (SP)


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


# ----------------------------------------------------------------------
def init_dense(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def rms_norm(x, w, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d: int, dtype):
    return jnp.zeros((d,), dtype)  # stored as (1 + w) offset form


# ----------------------------------------------------------------------
def rope_angles(positions, head_dim: int, theta: float):
    """positions [...,S] -> (cos, sin) [...,S, head_dim//2] fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B,S,H,D]; cos/sin [B,S,half] or [S,half]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def softcap(x, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(x / cap)
    return x


def activation(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


# ----------------------------------------------------------------------
def tree_stack(trees):
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stacked_specs(specs):
    """Prepend a None (layer-stack) axis to every PartitionSpec leaf."""
    return jax.tree.map(
        lambda s: P(None, *s), specs,
        is_leaf=lambda s: isinstance(s, P))


def remat_policy(name: str):
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.everything_saveable
