"""Mamba2 (SSD) mixer block: projections + causal depthwise conv +
chunked selective-state-space scan + gated RMSNorm.

Projections are stored separately (wx/wz/wB/wC/wdt) instead of one fused
in_proj so each piece can carry its own sharding spec (d_inner and heads
shard over 'model'; the group-shared B/C projections replicate).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.models import common
from repro.models.common import Runtime


def init_ssm(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    n = s.d_state
    ks = jax.random.split(key, 8)
    # dt bias init so softplus(dt) spans [dt_min, dt_max] (mamba default)
    dt = jnp.exp(jax.random.uniform(ks[6], (nh,), jnp.float32)
                 * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "wx": common.init_dense(ks[0], d, di, dtype),
        "wz": common.init_dense(ks[1], d, di, dtype),
        "wB": common.init_dense(ks[2], d, n, dtype),
        "wC": common.init_dense(ks[3], d, n, dtype),
        "wdt": common.init_dense(ks[4], d, nh, dtype),
        "conv_w": (jax.random.normal(ks[5], (s.conv_dim, di + 2 * n),
                                     jnp.float32) / math.sqrt(s.conv_dim)
                   ).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "A_log": jnp.log(1.0 + jax.random.uniform(ks[7], (nh,), jnp.float32) * 15.0),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": common.init_rms_norm(di, dtype),
        "wo": common.init_dense(jax.random.fold_in(key, 99), di, d, dtype),
    }


def ssm_specs(cfg):
    return {
        "wx": P(None, "model"),
        "wz": P(None, "model"),
        "wB": P(None, None),
        "wC": P(None, None),
        "wdt": P(None, "model"),
        "conv_w": P(None, None),
        "conv_b": P(None,),
        "A_log": P("model",),
        "D": P("model",),
        "dt_bias": P("model",),
        "norm": P("model",),
        "wo": P("model", None),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds. x [B,S,C]; w [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    y = sum(pad[:, i:i + s] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(y + b[None, None, :])


def _conv_step(state, x_new, w, b):
    """state [B,K-1,C]; x_new [B,C] -> (y [B,C], new_state)."""
    window = jnp.concatenate([state, x_new[:, None]], axis=1)   # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", window, w)
    return jax.nn.silu(y + b[None, :]), window[:, 1:]


def _project(params, x, cfg, rt: Runtime):
    cd = rt.compute_dtype
    xb = x @ common.cast(params["wx"], cd)
    z = x @ common.cast(params["wz"], cd)
    bv = x @ common.cast(params["wB"], cd)
    cv = x @ common.cast(params["wC"], cd)
    dt = x @ common.cast(params["wdt"], cd)
    return xb, z, bv, cv, dt


def ssm_forward(params, x, cfg, rt: Runtime, *, initial_state=None,
                return_state=False):
    """Train/prefill path. x [B,S,d] -> [B,S,d] (+ (conv_state, ssm_state))."""
    s = cfg.ssm
    b, sl, d = x.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    n = s.d_state
    xb, z, bv, cv, dt = _project(params, x, cfg, rt)
    conv_in = jnp.concatenate([xb, bv, cv], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"].astype(rt.compute_dtype),
                            params["conv_b"].astype(rt.compute_dtype))
    xb, bv, cv = (conv_out[..., :di], conv_out[..., di:di + n],
                  conv_out[..., di + n:])
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    y, final = ops.mamba_chunk_scan(
        xb.reshape(b, sl, nh, s.head_dim), dtv, A, bv, cv, params["D"],
        chunk=s.chunk, initial_state=initial_state, impl=rt.kernel_impl)
    y = y.reshape(b, sl, di)
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        params["norm"], cfg.norm_eps)
    out = y @ common.cast(params["wo"], rt.compute_dtype)
    if return_state:
        k = s.conv_dim - 1
        conv_state = jnp.pad(conv_in, ((0, 0), (k, 0), (0, 0)))[:, -k:]
        return out, (conv_state.astype(rt.compute_dtype), final)
    return out


def ssm_init_state(cfg, batch: int, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_state = jnp.zeros((batch, s.conv_dim - 1, di + 2 * s.d_state), dtype)
    ssm_state = jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32)
    return conv_state, ssm_state


def ssm_decode(params, x, state, cfg, rt: Runtime):
    """One-token decode. x [B,d]; state=(conv_state, ssm_state)."""
    s = cfg.ssm
    b, d = x.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    n = s.d_state
    conv_state, ssm_state = state
    xb, z, bv, cv, dt = _project(params, x[:, None, :], cfg, rt)
    conv_in = jnp.concatenate([xb[:, 0], bv[:, 0], cv[:, 0]], axis=-1)
    conv_out, conv_state = _conv_step(
        conv_state, conv_in, params["conv_w"].astype(rt.compute_dtype),
        params["conv_b"].astype(rt.compute_dtype))
    xb1, bv1, cv1 = (conv_out[:, :di], conv_out[:, di:di + n],
                     conv_out[:, di + n:])
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"][None, :])
    A = -jnp.exp(params["A_log"])
    y, ssm_state = ops.mamba_decode_step(
        ssm_state, xb1.reshape(b, nh, s.head_dim), dtv, A, bv1, cv1,
        params["D"])
    y = y.reshape(b, di)
    y = common.rms_norm(y * jax.nn.silu(z[:, 0].astype(jnp.float32)).astype(y.dtype),
                        params["norm"], cfg.norm_eps)
    out = y @ common.cast(params["wo"], rt.compute_dtype)
    return out, (conv_state, ssm_state)
