"""Mixture-of-Experts FFN with expert parallelism over the model axis.

Dispatch strategy (sort-based, no O(T*E*C) one-hot tensors):
activations enter the MoE replicated across the model axis (the same
layout TP gives the dense FFN), so every model shard routes *all* of its
data-shard's tokens, keeps only the slots owned by its local experts,
builds a static-capacity [E_local, C, d] buffer via a stable sort, runs
the expert matmuls, scatters back, and psums across the model axis —
one all-reduce, the same collective the dense TP FFN needs, and all
routing/sort work is shard-local (no global argsort collectives).

Token slots beyond an expert's capacity are dropped (standard static
-capacity semantics); Runtime.capacity_factor scales C (tests use a
large factor to verify the dropless limit equals the dense reference).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common
from repro.parallel.sharding import shard_map
from repro.models.common import Runtime


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d, ff, e = cfg.d_model, m.d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    params = {
        "router": common.init_dense(ks[0], d, e, jnp.float32),  # fp32 router
        "wg": (jax.random.normal(ks[1], (e, d, ff), jnp.float32) * std).astype(dtype),
        "wu": (jax.random.normal(ks[2], (e, d, ff), jnp.float32) * std).astype(dtype),
        "wd": (jax.random.normal(ks[3], (e, ff, d), jnp.float32)
               * (1.0 / math.sqrt(ff))).astype(dtype),
    }
    return params


def moe_specs(cfg):
    return {
        "router": P(None, None),
        "wg": P("model", None, None),
        "wu": P("model", None, None),
        "wd": P("model", None, None),
    }


def _capacity(tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(math.ceil(tokens * top_k / n_experts * factor))
    return max(4, min(c, tokens * top_k))


def _moe_local(x, router, wg, wu, wd, *, cfg, rt: Runtime, tp_axis: str,
               dp_axes: Tuple[str, ...], capacity: int):
    """Per-shard MoE body (runs under shard_map).
    x [Tl, d] local tokens; wg/wu/wd local expert slices [El, d|ff, ...]."""
    m = cfg.moe
    tl, d = x.shape
    el = wg.shape[0]
    k = m.top_k
    cd = rt.compute_dtype

    gates = jax.nn.softmax((x.astype(jnp.float32) @ router), axis=-1)  # [Tl,E]
    topv, topi = jax.lax.top_k(gates, k)                               # [Tl,k]
    topv = topv / jnp.maximum(topv.sum(axis=-1, keepdims=True), 1e-9)

    e0 = jax.lax.axis_index(tp_axis) * el
    flat_e = topi.reshape(-1)                                          # [Tl*k]
    flat_w = topv.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(tl), k)
    local_e = flat_e - e0
    is_local = (local_e >= 0) & (local_e < el)
    le = jnp.where(is_local, local_e, el)                              # el = drop bucket

    # stable argsort by expert == sort of the packed key le*(Tl*k)+slot:
    # one single-operand int32 sort instead of the (keys, iota) variadic
    # comparator sort argsort lowers to (~7x slower on XLA CPU; same
    # packing trick as core/fmmu/batch._insert_blocks)
    nk = tl * k
    if (el + 1) * nk < 2 ** 31:
        skey = jnp.sort(le.astype(jnp.int32) * nk
                        + jnp.arange(nk, dtype=jnp.int32))
        order = jnp.mod(skey, nk)
        sle = skey // nk
    else:                                  # huge shards: packing overflows
        order = jnp.argsort(le, stable=True)
        sle = le[order]
    counts = jnp.bincount(sle, length=el + 1)
    offsets = jnp.cumsum(counts) - counts
    pos = jnp.arange(tl * k) - offsets[sle]
    keep = (sle < el) & (pos < capacity)
    dst = jnp.where(keep, sle * capacity + pos, el * capacity)         # OOB = drop

    rows = x[flat_t[order]].astype(cd)                                 # [Tl*k, d]
    buf = jnp.zeros((el * capacity, d), cd).at[dst].set(rows, mode="drop")
    buf = buf.reshape(el, capacity, d)

    h = common.activation(jnp.einsum("ecd,edf->ecf", buf, common.cast(wg, cd)),
                          cfg.act)
    h = h * jnp.einsum("ecd,edf->ecf", buf, common.cast(wu, cd))
    y = jnp.einsum("ecf,efd->ecd", h, common.cast(wd, cd))
    y = y.reshape(el * capacity, d)

    back = y.at[dst].get(mode="fill", fill_value=0)                    # [Tl*k, d]
    w = jnp.where(keep, flat_w[order], 0.0).astype(jnp.float32)
    out = jnp.zeros((tl, d), jnp.float32).at[flat_t[order]].add(
        back.astype(jnp.float32) * w[:, None])
    out = jax.lax.psum(out, tp_axis)

    # load-balance auxiliary loss (Switch-style), global means via psum
    ohot = jax.nn.one_hot(topi, m.n_experts, dtype=jnp.float32).sum(axis=1)
    f_sum = ohot.sum(axis=0)
    p_sum = gates.sum(axis=0)
    n_tok = jnp.float32(tl)
    if dp_axes:
        f_sum = jax.lax.psum(f_sum, dp_axes)                           # [E]
        p_sum = jax.lax.psum(p_sum, dp_axes)
        n_tok = jax.lax.psum(n_tok, dp_axes)
    f = f_sum / (n_tok * k)
    pbar = p_sum / n_tok
    aux = m.n_experts * jnp.sum(f * pbar)
    aux = jax.lax.pmean(aux, tp_axis)  # identical on every shard
    return out.astype(x.dtype), aux


def apply_moe(params, x, cfg, rt: Runtime, ctx, *, dense_params=None):
    """x [B,S,d] -> ([B,S,d], aux_loss scalar). ctx: ParallelCtx."""
    from repro.models import mlp as mlp_mod
    m = cfg.moe
    b, s, d = x.shape
    # tiny batches (decode at global_batch < dp_size) replicate tokens
    # across the data axes instead of sharding them
    shard_tokens = (b % ctx.dp_size) == 0
    dp_axes = tuple(ctx.dp) if shard_tokens else ()
    tl = (b // ctx.dp_size if shard_tokens else b) * s
    cf = rt.capacity_factor if rt.capacity_factor is not None else m.capacity_factor
    capacity = _capacity(tl, m.n_experts, m.top_k, cf)

    body = functools.partial(_moe_local, cfg=cfg, rt=rt, tp_axis=ctx.tp,
                             dp_axes=dp_axes, capacity=capacity)
    if shard_tokens:
        dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    else:
        dp_spec = None
    fn = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(dp_spec, None), P(None, None),
                  P(ctx.tp, None, None), P(ctx.tp, None, None),
                  P(ctx.tp, None, None)),
        out_specs=(P(dp_spec, None), P()),
        check_vma=False)
    x2 = x.reshape(b * s, d)
    out, aux = fn(x2, params["router"], params["wg"], params["wu"],
                  params["wd"])
    out = out.reshape(b, s, d)
    if dense_params is not None:  # arctic: parallel dense residual MLP
        out = out + mlp_mod.apply_mlp(dense_params, x, cfg, rt)
    return out, aux * m.router_aux_weight


def apply_moe_dense_ref(params, x, cfg, rt: Runtime):
    """O(T*E) dense reference (tests): every expert runs every token."""
    m = cfg.moe
    cd = rt.compute_dtype
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    gates = jax.nn.softmax(x2.astype(jnp.float32) @ params["router"], axis=-1)
    topv, topi = jax.lax.top_k(gates, m.top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    full = jnp.zeros_like(gates).at[jnp.arange(x2.shape[0])[:, None], topi].set(topv)
    h = common.activation(jnp.einsum("td,edf->tef", x2.astype(cd),
                                     common.cast(params["wg"], cd)), cfg.act)
    h = h * jnp.einsum("td,edf->tef", x2.astype(cd), common.cast(params["wu"], cd))
    y = jnp.einsum("tef,efd->ted", h, common.cast(params["wd"], cd))
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), full)
    return out.reshape(b, s, d).astype(x.dtype)
