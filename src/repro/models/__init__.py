from repro.models.common import Runtime
from repro.models.model import Model, build_model
