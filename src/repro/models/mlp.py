"""Gated FFN (SwiGLU / GeGLU) with tensor-parallel specs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common
from repro.models.common import Runtime


def init_mlp(key, cfg, dtype, d_ff=None):
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": common.init_dense(k1, d, ff, dtype),
        "wu": common.init_dense(k2, d, ff, dtype),
        "wd": common.init_dense(k3, ff, d, dtype),
    }


def mlp_specs(cfg):
    return {
        "wg": P(None, "model"),
        "wu": P(None, "model"),
        "wd": P("model", None),
    }


def apply_mlp(params, x, cfg, rt: Runtime):
    cd = rt.compute_dtype
    g = common.activation(x @ common.cast(params["wg"], cd), cfg.act)
    u = x @ common.cast(params["wu"], cd)
    return (g * u) @ common.cast(params["wd"], cd)
