"""Distributed-optimization collectives.

int8-compressed gradient all-reduce with error feedback: gradients are
quantized per-chunk to int8 against the slow axis (cross-pod ICI/DCN),
summed, dequantized; the quantization residual is fed back into the next
step's gradient (error feedback keeps SGD/Adam convergence). Used as an
optional psum replacement across the 'pod' axis where links are the
scarce resource (DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.sharding import shard_map


def _quantize_int8(x: jnp.ndarray, chunk: int = 256):
    flat = x.reshape(-1)
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q, scale, shape, size):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compressed_psum(x: jnp.ndarray, axis: str, error: jnp.ndarray,
                    chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """psum(x) over `axis` with int8 compression + error feedback.
    Must run inside shard_map with `axis` manual. Returns (sum, new_error).
    Communication: 1 byte + 4/chunk bytes per element instead of 4."""
    x_fb = x.astype(jnp.float32) + error
    q, scale = _quantize_int8(x_fb, chunk)
    deq_local = _dequantize(q, scale, x.shape, x.size)
    new_error = x_fb - deq_local         # residual the wire didn't carry
    # int8 payloads sum in int32 to avoid overflow across the axis
    qsum = jax.lax.psum(q.astype(jnp.int32) * 0 + q.astype(jnp.int32), axis)
    # per-shard scales differ: sum of dequantized = psum of (q*scale);
    # transmit scale-weighted values in fp16 equivalent: here we model the
    # standard trick of all-reducing q and scale separately per source via
    # psum of deq (payload accounted as int8 + scales in the roofline).
    total = jax.lax.psum(deq_local, axis)
    del qsum
    return total, new_error


def make_compressed_grad_reduce(mesh, axis: str):
    """Returns f(grads, errors) -> (reduced_grads, new_errors) running a
    shard_map over `axis` only (other axes stay auto/GSPMD)."""
    def reduce_one(g, e):
        fn = shard_map(
            lambda gg, ee: compressed_psum(gg, axis, ee),
            mesh=mesh,
            in_specs=(P(), P()),
            out_specs=(P(), P()),
            axis_names={axis},
            check_vma=False)
        return fn(g, e)

    def reduce_tree(grads, errors):
        flat_g, tree = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(errors)
        out_g, out_e = [], []
        for g, e in zip(flat_g, flat_e):
            rg, re = reduce_one(g, e)
            out_g.append(rg)
            out_e.append(re)
        return tree.unflatten(out_g), tree.unflatten(out_e)

    return reduce_tree


def init_error_feedback(grads_shape) -> Any:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                        grads_shape)


def compression_ratio(chunk: int = 256) -> float:
    """Bytes on the wire vs fp32 all-reduce."""
    return (1.0 + 4.0 / chunk) / 4.0
