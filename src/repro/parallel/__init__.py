from repro.parallel.sharding import ParallelCtx, make_mesh, trivial_ctx, test_ctx
