"""Logical-axis sharding rules resolved onto a concrete mesh.

Model code emits *logical* PartitionSpecs using the names 'data' and
'model'. A ParallelCtx maps 'data' -> the (possibly compound) batch axes
(('pod','data') on the multi-pod mesh) and 'model' -> the tensor axis,
and replicates any dimension whose size does not divide its mesh extent
(e.g. arctic's 56 Q heads on a 16-way model axis) instead of relying on
implicit GSPMD padding — the decision is explicit and logged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist
    # on newer jax; Auto is the default behaviour either way, so fall
    # back cleanly on wheels that predate explicit axis types.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False,
              axis_names=None):
    """Version-portable shard_map: prefers the top-level jax.shard_map
    (check_vma / axis_names API), falls back to
    jax.experimental.shard_map on older wheels (check_rep; partial
    manualness expressed through its `auto` complement)."""
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_vma)
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map(f, **kw)


def channel_mesh(n_channels: int) -> Mesh:
    """1-D mesh over the FMMU channel axis (ISSUE-5 map sharding): one
    device per channel. CI's tier1-sharded lane provides 8 host-platform
    devices via XLA_FLAGS=--xla_force_host_platform_device_count=8; on
    real hardware the channels ride the accelerator mesh."""
    if len(jax.devices()) < n_channels:
        raise ValueError(
            f"channel_mesh({n_channels}): only {len(jax.devices())} "
            "devices visible (set --xla_force_host_platform_device_count"
            " or shard with the vmap lowering instead)")
    return make_mesh((n_channels,), ("channel",))


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh
    dp: Tuple[str, ...] = ("data",)   # batch axes, outermost first
    tp: str = "model"
    ch: Optional[str] = None   # FMMU channel axis (map-state sharding);
    #                            None = unsharded map (pre-ISSUE-5)
    fsdp_params: bool = False  # ZeRO-3/FSDP: also shard params over dp
    spec_dim_fallback: bool = False  # non-dividing dim: slide the axis to
    #                                  the next dividing dim (e.g. arctic's
    #                                  56 heads -> shard head_dim instead)

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp]))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp])

    @property
    def ch_size(self) -> int:
        return int(self.mesh.shape[self.ch]) if self.ch else 1

    @property
    def n_devices(self) -> int:
        return int(np.prod(list(self.mesh.shape.values())))

    def axis_size(self, logical) -> int:
        names = self._physical(logical)
        if names is None:
            return 1
        if isinstance(names, str):
            return int(self.mesh.shape[names])
        return int(np.prod([self.mesh.shape[a] for a in names]))

    def _physical(self, logical):
        if logical is None:
            return None
        if logical == "data":
            return self.dp if len(self.dp) > 1 else self.dp[0]
        if logical == "model":
            return self.tp
        if logical == "channel":
            return self.ch
        if isinstance(logical, (tuple, list)):
            out = []
            for l in logical:
                p = self._physical(l)
                if p is None:
                    continue
                out.extend(p if isinstance(p, tuple) else (p,))
            return tuple(out) if out else None
        return logical  # already a physical axis name

    def resolve(self, spec: P, shape: Optional[Tuple[int, ...]] = None,
                fsdp: bool = False) -> P:
        """Logical spec -> physical spec; non-dividing dims replicated."""
        phys = []
        carry = []   # axes displaced by non-dividing dims (fallback mode)
        for i, s in enumerate(spec):
            p = self._physical(s)
            if p is None and carry and shape is not None and i < len(shape):
                cand = carry[0]
                ext = (int(np.prod([self.mesh.shape[a] for a in cand]))
                       if isinstance(cand, tuple)
                       else int(self.mesh.shape[cand]))
                if shape[i] % ext == 0:
                    p = carry.pop(0)
            if p is not None and shape is not None and i < len(shape):
                ext = (int(np.prod([self.mesh.shape[a] for a in p]))
                       if isinstance(p, tuple) else int(self.mesh.shape[p]))
                if shape[i] % ext != 0:
                    if self.spec_dim_fallback:
                        carry.append(p)
                    p = None  # replicate: dimension does not divide
            phys.append(p)
        if fsdp and shape is not None and len(shape) >= 2:
            # ZeRO-3: shard the largest still-open dim over the data axes
            # (GSPMD inserts the just-in-time all-gathers)
            dp = self.dp if len(self.dp) > 1 else self.dp[0]
            best, best_n = -1, 0
            for i, n in enumerate(shape):
                cur = phys[i] if i < len(phys) else None
                if cur is None and n % self.dp_size == 0 and n > best_n:
                    best, best_n = i, n
            if best >= 0:
                while len(phys) <= best:
                    phys.append(None)
                phys[best] = dp
        while phys and phys[-1] is None:
            phys.pop()
        return P(*phys)

    def sharding(self, spec: P, shape: Optional[Tuple[int, ...]] = None,
                 fsdp: bool = False) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(spec, shape, fsdp=fsdp))

    def constraint(self, x, spec: P):
        return jax.lax.with_sharding_constraint(
            x, self.sharding(spec, tuple(x.shape)))

    def tree_shardings(self, specs, shapes, fsdp: bool = False):
        """specs: pytree of logical P; shapes: matching pytree of
        array-likes or ShapeDtypeStructs. fsdp applies ZeRO-3 data-axis
        sharding on top (parameter trees only)."""
        return jax.tree.map(
            lambda s, a: self.sharding(s, tuple(a.shape), fsdp=fsdp),
            specs, shapes,
            is_leaf=lambda s: isinstance(s, P))


def trivial_ctx() -> ParallelCtx:
    """1x1 mesh for single-device tests; same axis names as production."""
    return ParallelCtx(mesh=make_mesh((1, 1), ("data", "model")))


def test_ctx(data: int = 2, model: int = 2) -> ParallelCtx:
    return ParallelCtx(mesh=make_mesh((data, model), ("data", "model")))


def channel_ctx(channels: int, data: int = 1,
                model: int = 1) -> ParallelCtx:
    """Mesh with an FMMU 'channel' axis alongside data/model: logical
    'channel' specs resolve onto it (map-state leaves carry a leading
    channel dim), everything else is unaffected."""
    return ParallelCtx(
        mesh=make_mesh((data, model, channels),
                       ("data", "model", "channel")),
        ch="channel")
