"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

The layer stack is split into S stages (S = pipe axis size); microbatches
stream through stages with collective_permute handoffs inside a
shard_map. Schedule: standard GPipe fill/drain — T = M + S - 1 ticks for
M microbatches; each tick every stage processes (at most) one resident
microbatch, then activations rotate one stage down the ring.

Used as an optional wrapper for depth-dominated models when the 2D
(data, model) mesh runs out of efficient TP width; off by default for
the assigned meshes (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.sharding import shard_map


def pipeline_apply(mesh, stage_fn: Callable, stage_params: Any, x, *,
                   n_microbatches: int, axis: str = "pipe"):
    """Run x through S pipeline stages.

    stage_fn(params_slice, x_mb) -> x_mb     (one stage's layers)
    stage_params: pytree with leading [S] axis (stage slices)
    x [B, ...] with B % n_microbatches == 0
    Returns stage_fn applied S times to every microbatch, with GPipe
    scheduling across the 'pipe' mesh axis.
    """
    s = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0
    mb = b // n_microbatches

    def staged(params_local, x_all):
        # params_local: this stage's slice [1, ...] -> squeeze
        params_local = jax.tree.map(lambda t: t[0], params_local)
        sid = jax.lax.axis_index(axis)
        n_ticks = n_microbatches + s - 1
        xs = x_all.reshape(n_microbatches, mb, *x_all.shape[1:])
        # circular buffer of the activation each stage currently holds
        hold = jnp.zeros((mb,) + x_all.shape[1:], x_all.dtype)
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            hold, outs = carry
            # stage 0 ingests microbatch t (if in range)
            take = (sid == 0) & (t < n_microbatches)
            mb_in = xs[jnp.clip(t, 0, n_microbatches - 1)]
            hold = jnp.where(take, mb_in, hold)
            # every stage runs its layers on what it holds
            hold = stage_fn(params_local, hold)
            # last stage emits microbatch t - (s - 1)
            out_idx = t - (s - 1)
            emit = (sid == s - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: o.at[jnp.clip(out_idx, 0, n_microbatches - 1)]
                .set(hold),
                lambda o: o, outs)
            # rotate activations one stage down the ring
            perm = [(i, (i + 1) % s) for i in range(s)]
            hold = jax.lax.ppermute(hold, axis, perm)
            return (hold, outs), None

        (hold, outs), _ = jax.lax.scan(tick, (hold, outs),
                                       jnp.arange(n_ticks))
        # outs live on the last stage; broadcast to all so out_specs can
        # be replicated over the pipe axis
        outs = jax.lax.psum(
            jnp.where(sid == s - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(b, *x_all.shape[1:])

    fn = shard_map(
        staged, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False)
    return fn(stage_params, x)


def split_stages(params_stacked: Any, n_stages: int) -> Any:
    """Reshape a [L, ...]-stacked layer pytree into [S, L//S, ...]."""
    def one(t):
        l = t.shape[0]
        assert l % n_stages == 0, f"{l} layers not divisible by {n_stages}"
        return t.reshape(n_stages, l // n_stages, *t.shape[1:])

    return jax.tree.map(one, params_stacked)
